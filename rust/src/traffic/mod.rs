//! Analytic data-movement model: per-iteration GPU load/offload byte counts
//! for the three schedules the paper analyzes (§1, §3.2–3.4).
//!
//! All quantities are *per GPU* for one training iteration of an N-layer
//! model with M micro-batches of size B at sequence length T. With FSDP over
//! `shards` GPUs, parameter/gradient/optimizer bytes divide by `shards`
//! (each GPU moves only its shard over its own PCIe link; the all-gather is
//! inter-GPU traffic, not host traffic).
//!
//! The `*_dp` methods give the W-way data-parallel aggregates (micro-batches
//! split contiguously across W full model replicas — the `--workers W`
//! runtime/sim dimension): SSD/host traffic is the share-wise sum, which
//! collapses field-for-field to the single-worker forms at W = 1
//! (property-tested), and [`Workload::allreduce_bytes_per_worker`] is the
//! ring traffic that stays OFF the host tier. All ring byte counts derive
//! from the [`crate::coordinator::dist`] helpers (one source of truth with
//! the runtime engine and the event simulator): the all-reduce counts the
//! *effective* (active) workers — ranks without a micro-batch share move
//! nothing — while the `--shard-optimizer` reduce-scatter / all-gather
//! forms span the whole group, because every configured rank owns an
//! optimizer shard. The sharded forms also give the per-rank optimizer
//! SSD round trip (~1/W of the rank-0 path's), the quantity the
//! fig13_shard bench sweeps.
//!
//! The CPU-DRAM cache tier (`--cpu-cache-mb`) has its closed forms here
//! too: [`Workload::ssd_working_set_bytes`] + [`Workload::cache_absorbs`]
//! give the fit-or-nothing LRU law the event sim applies, and the
//! `store_*`/`cached_store_*` family mirrors the runtime `TensorStore`
//! byte counters exactly (what the fig14_store bench cross-checks).
//!
//! The multi-path planner (`--planned`) has its per-tier closed forms in
//! the `planned_*` family: [`Workload::planned_read_bytes`] applies the
//! runtime planner's exact per-object extent split
//! ([`crate::memory::plan_shares`]) to every live store object, yielding
//! one byte count per path (DRAM / each NVMe / remote) that sums back to
//! [`Workload::store_read_bytes`] exactly — the per-path mirror of the
//! runtime `PlannedStore::path_stats` counters the fig16_mlp bench
//! cross-checks.
//!
//! Two unit systems coexist. The schedule forms above and the legacy
//! `store_*` family count checkpoints in the PAPER's low-precision wire
//! width ([`BYTES_LP`] = 2 B/elem) — the analytic convention every figure
//! uses. The `*_enc` family instead counts the bytes the runtime store
//! actually moves under a [`PrecisionPolicy`](crate::memory::codec): each
//! object category at its codec's width (f32 moments at 4 B/elem;
//! checkpoints at 4 B strict / 2 B under `--precision mixed:*`), matching
//! the runtime `bytes_read`/`bytes_written` counters byte-for-byte.

use crate::coordinator::dist::{
    ring_allgather_bytes, ring_reduce_scatter_bytes, ring_traffic_bytes,
};
use crate::modelcfg::{ModelCfg, BYTES_FP, BYTES_LP};

/// Inputs to the traffic model.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub model: ModelCfg,
    pub micro_batch: u64,
    pub seq_len: u64,
    /// Number of micro-batches per iteration (gradient accumulation factor).
    pub m: u64,
    /// FSDP shard count (1 = single GPU).
    pub shards: u64,
}

/// GPU↔host traffic breakdown, bytes per iteration per GPU.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Traffic {
    /// Host→GPU: low-precision parameters.
    pub param_load: u64,
    /// Host→GPU: activation checkpoints (+ inter-layer gradients in bwd).
    pub ckpt_load: u64,
    /// Host→GPU: gradient-accumulation buffer fetches.
    pub grad_load: u64,
    /// GPU→Host: checkpoints (+ inter-layer gradients).
    pub ckpt_store: u64,
    /// GPU→Host: gradient offloads.
    pub grad_store: u64,
}

impl Traffic {
    pub fn total_load(&self) -> u64 {
        self.param_load + self.ckpt_load + self.grad_load
    }

    pub fn total_store(&self) -> u64 {
        self.ckpt_store + self.grad_store
    }

    pub fn total(&self) -> u64 {
        self.total_load() + self.total_store()
    }
}

impl Workload {
    /// Total model low-precision bytes per shard (the paper's `ms`).
    pub fn ms_lp(&self) -> u64 {
        self.model.n_layers * self.model.params_per_layer() * BYTES_LP / self.shards
    }

    /// Full-precision gradient bytes per shard (`2·ms` in the paper's units).
    pub fn grad_fp(&self) -> u64 {
        self.model.n_layers * self.model.params_per_layer() * BYTES_FP / self.shards
    }

    /// One micro-batch's aggregated checkpoint bytes across all layers
    /// (the paper's `cs`): N inter-layer checkpoints of B·T·D.
    pub fn cs(&self) -> u64 {
        self.model.n_layers * self.model.ckpt_bytes_lp(self.micro_batch, self.seq_len)
    }

    /// One layer's checkpoint bytes for one micro-batch.
    pub fn ckpt_layer(&self) -> u64 {
        self.model.ckpt_bytes_lp(self.micro_batch, self.seq_len)
    }

    /// §3.3 — horizontal gradient accumulation (ZeRO-Infinity).
    ///
    /// Parameters: loaded once per forward and once per backward-with-
    /// recompute, for every micro-batch → 2·M·ms.
    /// Checkpoints: written once in fwd, read once in bwd, per micro-batch
    /// → M·cs each way.
    /// Gradients: micro-batch 1 offloads (2·ms); each of the remaining M-1
    /// fetches and re-offloads → loads 2(M-1)·ms_fp... in the paper's `2ms`
    /// = fp32 gradient bytes notation: total (2M-1)·grad_fp moved, split
    /// (M-1) loads / M stores.
    pub fn horizontal(&self) -> Traffic {
        Traffic {
            param_load: 2 * self.m * self.ms_lp(),
            ckpt_load: self.m * self.cs(),
            grad_load: (self.m - 1) * self.grad_fp(),
            ckpt_store: self.m * self.cs(),
            grad_store: self.m * self.grad_fp(),
        }
    }

    /// §3.4 — vertical gradient accumulation (GreedySnake).
    ///
    /// Parameters: loaded once for the whole forward and once for the whole
    /// backward (all micro-batches share the resident layer) → 2·ms.
    /// Gradients: accumulated on-GPU, offloaded once → grad_fp.
    /// Checkpoints: fwd writes M·cs and re-reads (M-1)/M of it (the first
    /// micro-batch's activation stays resident across the layer boundary via
    /// alternating order, §4.2); bwd reads M·cs for recomputation and moves
    /// inter-layer gradients both ways ((M-1)/M resident trick applies too).
    pub fn vertical(&self) -> Traffic {
        let per_layer = self.ckpt_layer();
        let n = self.model.n_layers;
        // fwd: store M ckpts/layer; load (M-1)/layer.
        let fwd_store = n * self.m * per_layer;
        let fwd_load = n * (self.m - 1) * per_layer;
        // bwd: load M input ckpts/layer (recompute) + (M-1) inter-layer
        // grads/layer; store (M-1) inter-layer grads/layer (last layer's
        // boundary stays on GPU).
        let bwd_load = n * self.m * per_layer + n * (self.m - 1) * per_layer;
        let bwd_store = n * (self.m - 1) * per_layer;
        Traffic {
            param_load: 2 * self.ms_lp(),
            ckpt_load: fwd_load + bwd_load,
            grad_load: 0,
            ckpt_store: fwd_store + bwd_store,
            grad_store: self.grad_fp(),
        }
    }

    /// Chunked-vertical (`chunked:G`): micro-batches processed in ⌈M/G⌉
    /// contiguous chunks, each swept vertically through the whole stack —
    /// the vertical schedule's graceful degradation when only G activation
    /// fronts fit in GPU memory.
    ///
    /// Each chunk behaves like a vertical pass over its own micro-batches
    /// (parameters twice per chunk, per-chunk checkpoint staging), and the
    /// per-layer gradient buffer round-trips between chunks exactly like
    /// horizontal's does between micro-batches. The formula degenerates to
    /// [`Workload::vertical`] at G ≥ M and to [`Workload::horizontal`] at
    /// G = 1 field-for-field. In between, more chunks trade parameter and
    /// gradient traffic for checkpoint traffic, so whenever per-layer
    /// parameter + gradient bytes outweigh checkpoint bytes
    /// (2·ms + grad > 2·N·c — true for every transformer in the paper's
    /// model zoo) bytes read order vertical ≤ chunked ≤ horizontal,
    /// monotonically in ⌈M/G⌉ (property-tested on GPT-65B). Checkpoint-
    /// dominated shapes (B·T ≫ hidden on a tiny model) can invert this.
    pub fn chunked_vertical(&self, group: u64) -> Traffic {
        let g = group.max(1);
        let k = self.m.div_ceil(g); // number of chunks
        let per_layer = self.ckpt_layer();
        let n = self.model.n_layers;
        let mut t = Traffic {
            param_load: 2 * k * self.ms_lp(),
            grad_load: (k - 1) * self.grad_fp(),
            grad_store: k * self.grad_fp(),
            ..Traffic::default()
        };
        for c in 0..k {
            // chunk size (last chunk may be short)
            let gi = (self.m - c * g).min(g);
            // per-chunk vertical staging (see `vertical` for the counting)
            t.ckpt_store += n * gi * per_layer + n * (gi - 1) * per_layer;
            t.ckpt_load += n * (gi - 1) * per_layer // fwd re-reads
                + n * gi * per_layer // bwd recompute reads
                + n * (gi - 1) * per_layer; // bwd inter-layer grads
        }
        t
    }

    /// Contiguous micro-batch shares of `m` across `workers` data-parallel
    /// workers — the same split [`crate::coordinator::dist::partition`]
    /// gives the runtime engine (one source of truth for the partition
    /// policy), with idle workers' empty shares dropped.
    pub fn dp_shares(&self, workers: u64) -> Vec<u64> {
        crate::coordinator::dist::partition(self.m as usize, workers.max(1) as usize)
            .iter()
            .map(|r| r.len() as u64)
            .filter(|&s| s > 0)
            .collect()
    }

    /// Sum a per-worker closed form over the data-parallel shares: each
    /// active worker is a full model replica running `f` over its own
    /// micro-batch share, so aggregate SSD/host traffic is the share-wise
    /// sum. At `workers == 1` this IS the single-worker form (the collapse
    /// property the proptests pin down).
    fn dp_sum(&self, workers: u64, f: impl Fn(&Workload) -> Traffic) -> Traffic {
        let mut total = Traffic::default();
        for share in self.dp_shares(workers) {
            let t = f(&Workload { m: share, ..*self });
            total.param_load += t.param_load;
            total.ckpt_load += t.ckpt_load;
            total.grad_load += t.grad_load;
            total.ckpt_store += t.ckpt_store;
            total.grad_store += t.grad_store;
        }
        total
    }

    /// Aggregate per-iteration traffic of W-way data-parallel vertical
    /// scheduling: every worker reloads the FULL parameter set once per
    /// pass (param traffic ×W — the multi-worker SSD pressure the fig12
    /// scaling bench measures), while checkpoint totals *shrink* slightly
    /// (each worker keeps its own boundary micro-batch resident).
    pub fn vertical_dp(&self, workers: u64) -> Traffic {
        self.dp_sum(workers, |w| w.vertical())
    }

    /// W-way horizontal: parameters reload per (worker micro-batch) so the
    /// total is W-invariant; gradient round trips split per worker.
    pub fn horizontal_dp(&self, workers: u64) -> Traffic {
        self.dp_sum(workers, |w| w.horizontal())
    }

    /// W-way chunked-vertical (each worker chunks its own share).
    pub fn chunked_vertical_dp(&self, group: u64, workers: u64) -> Traffic {
        self.dp_sum(workers, |w| w.chunked_vertical(group))
    }

    /// Number of workers that actually receive a micro-batch share
    /// (min(W, M) for M ≥ 1) — the rank count the all-reduce runs over,
    /// matching the runtime engine's `active` count so the closed form and
    /// the measured `allreduce_bytes` can never disagree when W > M.
    pub fn effective_workers(&self, workers: u64) -> u64 {
        (self.dp_shares(workers).len() as u64).max(1)
    }

    /// Total ring all-reduce bytes per iteration to combine the fp32
    /// gradients, summed across ranks: 2·(Wₑ−1)·grad bytes for Wₑ
    /// *effective* workers — exactly the runtime's
    /// [`ring_traffic_bytes`] accounting. Inter-GPU traffic — it rides the
    /// interconnect, not the SSD, which is why it does not appear in
    /// [`Traffic`].
    pub fn allreduce_bytes_total(&self, workers: u64) -> u64 {
        ring_traffic_bytes(self.effective_workers(workers) as usize, self.grad_fp())
    }

    /// Ring all-reduce bytes EACH active worker moves per iteration:
    /// `total ⧸ Wₑ` rounded up (2·(Wₑ−1)/Wₑ · grad bytes); 0 when only one
    /// worker is active. Same effective-worker count and rounding as
    /// [`Workload::allreduce_bytes_total`] — `per_worker · Wₑ` covers the
    /// total with less than one worker's slack (property-tested).
    pub fn allreduce_bytes_per_worker(&self, workers: u64) -> u64 {
        let active = self.effective_workers(workers);
        self.allreduce_bytes_total(workers).div_ceil(active)
    }

    /// Total gradient reduce-scatter bytes per iteration under
    /// `--shard-optimizer`: (W−1)·grad bytes over the whole group — every
    /// configured rank owns an optimizer shard and receives its slice, so
    /// the group size (not the active count) is the ring size.
    pub fn reduce_scatter_bytes_total(&self, workers: u64) -> u64 {
        ring_reduce_scatter_bytes(workers.max(1) as usize, self.grad_fp())
    }

    /// Reduce-scatter bytes EACH rank moves under `--shard-optimizer`
    /// (total ⧸ W rounded up).
    pub fn reduce_scatter_bytes_per_worker(&self, workers: u64) -> u64 {
        self.reduce_scatter_bytes_total(workers).div_ceil(workers.max(1))
    }

    /// Total parameter all-gather bytes per iteration under
    /// `--shard-optimizer`: (W−1)·ms (low-precision parameters) over the
    /// whole group, republishing each rank's updated shard before the next
    /// iteration's prefetch. NOTE: this closed form models the paper's
    /// bf16-parameter gather; the runtime's measured
    /// `RunLog::allgather_bytes` counts f32 parameter bytes (the
    /// reproduction substrate keeps params in f32), so the two share the
    /// (W−1)·payload *shape* but differ by the precision factor — only the
    /// gradient ring (fp32 in both) matches byte-for-byte.
    pub fn allgather_bytes_total(&self, workers: u64) -> u64 {
        ring_allgather_bytes(workers.max(1) as usize, self.ms_lp())
    }

    /// All-gather bytes EACH rank moves under `--shard-optimizer`
    /// (total ⧸ W rounded up).
    pub fn allgather_bytes_per_worker(&self, workers: u64) -> u64 {
        self.allgather_bytes_total(workers).div_ceil(workers.max(1))
    }

    /// Optimizer-state bytes per FSDP shard (master + m + v, fp32) — the
    /// paper's `o` summed over the stack; the perfmodel's
    /// [`o_bytes`](crate::perfmodel::SystemParams::o_bytes) × N.
    pub fn opt_state_bytes(&self) -> u64 {
        self.model.n_layers * self.model.layer_opt_state_bytes() / self.shards
    }

    /// Per-iteration optimizer-state SSD round trip with fully SSD-resident
    /// states: every byte is read before the update and written back after
    /// → 2·o·N. On the rank-0 path ONE rank moves all of it.
    pub fn opt_ssd_round_trip_bytes(&self) -> u64 {
        2 * self.opt_state_bytes()
    }

    /// Per-RANK optimizer-state SSD round trip under `--shard-optimizer`:
    /// each rank round-trips only its 1/W shard (total ⧸ W rounded up) —
    /// the ~1/W scaling the fig13_shard bench measures, and the reason the
    /// CPU/SSD optimizer path stops being the W-invariant bottleneck.
    pub fn sharded_opt_ssd_bytes_per_rank(&self, workers: u64) -> u64 {
        self.opt_ssd_round_trip_bytes().div_ceil(workers.max(1))
    }

    /// Master-parameter f32 bytes per shard — the persisted parameter
    /// state `--param-persist` keeps on SSD (the reproduction substrate's
    /// master params are f32, so this equals [`Workload::grad_fp`]; the
    /// manifest-dependent embedding/head group rides the same ~1/W law but
    /// is outside the model-zoo forms).
    pub fn param_state_bytes(&self) -> u64 {
        self.model.n_layers * self.model.params_per_layer() * BYTES_FP / self.shards
    }

    /// Per-iteration parameter-persistence SSD round trip under
    /// `--param-persist`: every master-parameter byte is read before the
    /// update and its updated value written back after → 2·p·N (f32).
    /// Without sharding ONE rank moves all of it.
    pub fn param_ssd_round_trip_bytes(&self) -> u64 {
        2 * self.param_state_bytes()
    }

    /// Per-RANK parameter-persistence SSD round trip under
    /// `--shard-optimizer --param-persist`: each rank round-trips only its
    /// ~1/W parameter shard (total ⧸ W rounded up) — the ~1/W scaling the
    /// fig17_elastic bench pins against the runtime's per-rank
    /// `ParamShardCounters`.
    pub fn sharded_param_ssd_bytes_per_rank(&self, workers: u64) -> u64 {
        self.param_ssd_round_trip_bytes().div_ceil(workers.max(1))
    }

    // ---- CPU-DRAM cache tier (closed forms shared by runtime + sim) ------

    /// SSD-resident working set of one iteration under placement shares
    /// (`*_cpu` = fraction already in CPU DRAM; the SSD keeps the rest):
    /// low-precision parameters, all M live checkpoints, and the optimizer
    /// states. This is what a DRAM cache tier must hold to absorb the
    /// schedule's repeat SSD traffic.
    pub fn ssd_working_set_bytes(&self, param_cpu: f64, ckpt_cpu: f64, opt_cpu: f64) -> u64 {
        let param = (1.0 - param_cpu) * self.ms_lp() as f64;
        let ckpt = (1.0 - ckpt_cpu) * (self.m * self.cs()) as f64;
        let opt = (1.0 - opt_cpu) * self.opt_state_bytes() as f64;
        (param + ckpt + opt).ceil() as u64
    }

    /// The fit-or-nothing LRU law: a bounded cache in front of cyclically
    /// swept state absorbs ALL repeat traffic when the working set fits and
    /// essentially NONE when it does not (a cyclic sweep over a set larger
    /// than the cache evicts every entry before its re-use — LRU's
    /// pathological case). Runtime ([`crate::memory::CachedStore`]), event
    /// sim (`sim::simulate_store`), and these closed forms all apply this
    /// same law, so the three stacks agree on absorbed bytes.
    pub fn cache_absorbs(&self, working_set: u64, cache_bytes: u64) -> bool {
        working_set > 0 && cache_bytes >= working_set
    }

    // ---- runtime TensorStore byte counters (exact mirrors) ---------------

    /// The m+v moment bytes the RUNTIME keeps on its store per shard (fp32;
    /// master parameters stay host-resident in `ModelState`, so unlike
    /// [`Workload::opt_state_bytes`] this counts 2 — not 3 — state streams).
    pub fn runtime_moment_bytes(&self) -> u64 {
        2 * self.model.n_layers * self.model.params_per_layer() * BYTES_FP / self.shards
    }

    /// Bytes the runtime's `TensorStore` READS per steady-state iteration:
    /// every moment object round-trips once per iteration (`opt_on_ssd`)
    /// and every (layer, micro-batch) checkpoint is read back once
    /// (`ckpt_on_ssd`). Exactly the per-step `StepStats::ssd_bytes_read` of
    /// an uncached run — the quantity the cache tier absorbs.
    pub fn store_read_bytes(&self, opt_on_ssd: bool, ckpt_on_ssd: bool) -> u64 {
        // numerically the working set: every live store byte is read exactly
        // once per iteration (moments round-trip, checkpoints read back), so
        // the two closed forms are one expression — kept as one function so
        // they cannot drift apart silently
        self.store_working_set_bytes(opt_on_ssd, ckpt_on_ssd)
    }

    /// Bytes the runtime's `TensorStore` WRITES per steady-state iteration
    /// (same symmetry: moments written back, checkpoints stored once).
    pub fn store_write_bytes(&self, opt_on_ssd: bool, ckpt_on_ssd: bool) -> u64 {
        self.store_read_bytes(opt_on_ssd, ckpt_on_ssd)
    }

    /// The runtime store's working set: all live moment objects plus the
    /// peak live checkpoint set (all M·N checkpoints at the fwd/bwd turn).
    pub fn store_working_set_bytes(&self, opt_on_ssd: bool, ckpt_on_ssd: bool) -> u64 {
        (if opt_on_ssd { self.runtime_moment_bytes() } else { 0 })
            + (if ckpt_on_ssd { self.m * self.cs() } else { 0 })
    }

    /// Residual SSD reads per iteration under a DRAM cache of `cache_bytes`
    /// in front of the runtime store — the fit-or-nothing law: 0 when the
    /// working set fits (every get is a DRAM hit; the measured
    /// `RunLog::ssd_read` of a cached run is exactly 0), the full
    /// [`Workload::store_read_bytes`] when it does not.
    pub fn cached_store_read_bytes(
        &self,
        opt_on_ssd: bool,
        ckpt_on_ssd: bool,
        cache_bytes: u64,
    ) -> u64 {
        let ws = self.store_working_set_bytes(opt_on_ssd, ckpt_on_ssd);
        if self.cache_absorbs(ws, cache_bytes) {
            0
        } else {
            self.store_read_bytes(opt_on_ssd, ckpt_on_ssd)
        }
    }

    // ---- serve (forward-only decode) closed forms ------------------------

    /// Layer-parameter LOADS one decode pass of the serve engine performs
    /// over a batch of `m` lanes (concurrent sequences) under a
    /// `chunked:G`-style grouping: the lanes sweep the stack in ⌈M/G⌉
    /// chunks, each re-streaming every layer once — N·⌈M/G⌉ loads. `G ≥ M`
    /// is the vertical decode order (N loads per token step, the batched-
    /// decode amortization), `G = 1` the horizontal one (N·M). This count
    /// is unit-free, so it mirrors the runtime engine EXACTLY: the serve
    /// engine's per-pass parameter-stream bytes are this count times its
    /// per-layer base-image bytes (property-pinned in `tests/proptests.rs`
    /// against `schedule::param_loads` of the actual forward order).
    pub fn serve_param_loads(&self, group: u64) -> u64 {
        self.model.n_layers * self.m.div_ceil(group.max(1))
    }

    /// Parameter bytes the serve engine STREAMS per decode pass in the
    /// paper's wire units: exactly the TRAINING forward leg of the schedule
    /// forms — half the round-trip `param_load` of
    /// [`Workload::chunked_vertical`] (which degenerates to
    /// [`Workload::vertical`] at G ≥ M and [`Workload::horizontal`] at
    /// G = 1 — a forward-only pass loads each resident layer once, not
    /// twice). Identity: `serve_param_read_bytes(g) ==
    /// chunked_vertical(g).param_load / 2`, property-pinned below.
    pub fn serve_param_read_bytes(&self, group: u64) -> u64 {
        self.m.div_ceil(group.max(1)) * self.ms_lp()
    }

    /// Per-tenant adapter bytes riding one decode pass: every layer load
    /// also streams the owning tenant's `adapter_*` delta for that layer,
    /// sized `1/denom` of the layer's parameters (the runtime provisions
    /// `numel/64`-element deltas; the closed form takes the denominator so
    /// the two stay one expression).
    pub fn serve_adapter_read_bytes(&self, group: u64, denom: u64) -> u64 {
        self.serve_param_read_bytes(group) / denom.max(1)
    }

    /// The serve store's working set under T tenants: ONE shared base image
    /// (the multi-tenant sharing law — base bytes do not scale with T) plus
    /// each tenant's adapter set. This is what a DRAM cache must hold to
    /// absorb the decode re-streaming; the same fit-or-nothing
    /// [`Workload::cache_absorbs`] law applies on top.
    pub fn serve_working_set_bytes(&self, tenants: u64, denom: u64) -> u64 {
        self.ms_lp() + tenants * (self.ms_lp() / denom.max(1))
    }

    // ---- multi-path planner closed forms (`--planned` mirror) ------------

    /// The live store objects of one steady-state iteration, as
    /// `(count, bytes_each)` groups — the granularity the runtime planner
    /// splits at: two fp32 moment streams per layer (`opt_on_ssd`) and one
    /// checkpoint object per (layer, micro-batch) (`ckpt_on_ssd`,
    /// paper-width units like the legacy `store_*` family).
    fn store_objects(&self, opt_on_ssd: bool, ckpt_on_ssd: bool) -> Vec<(u64, u64)> {
        let mut groups = Vec::new();
        if opt_on_ssd {
            let moment = self.model.params_per_layer() * BYTES_FP / self.shards;
            groups.push((2 * self.model.n_layers, moment));
        }
        if ckpt_on_ssd {
            groups.push((self.m * self.model.n_layers, self.ckpt_layer()));
        }
        groups
    }

    /// Per-PATH bytes the planned store reads per steady-state iteration:
    /// each live object is read once, split over the paths by the runtime
    /// planner's exact extent arithmetic ([`crate::memory::plan_shares`]
    /// under the same `weights` — [`crate::memory::path_weight`] of each
    /// path's bandwidth). One entry per path, in the planner's path order
    /// (DRAM first if weighted, then each NVMe, then remote). Conservation
    /// is exact: the entries sum to [`Workload::store_read_bytes`]
    /// object-for-object (no rounding slack), which is how these forms
    /// mirror the runtime `path_stats` counters byte-for-byte (assuming no
    /// DRAM-capacity spill — a full DRAM tier shifts its share onto the
    /// other paths at plan time).
    pub fn planned_read_bytes(
        &self,
        opt_on_ssd: bool,
        ckpt_on_ssd: bool,
        weights: &[u64],
    ) -> Vec<u64> {
        let mut per_path = vec![0u64; weights.len()];
        for (count, bytes) in self.store_objects(opt_on_ssd, ckpt_on_ssd) {
            let shares = crate::memory::plan_shares(bytes, weights);
            for (acc, s) in per_path.iter_mut().zip(shares) {
                *acc += count * s;
            }
        }
        per_path
    }

    /// Per-path bytes WRITTEN per steady-state iteration — the same
    /// symmetry as the aggregate forms (moments written back, checkpoints
    /// stored once, identical per-object splits).
    pub fn planned_write_bytes(
        &self,
        opt_on_ssd: bool,
        ckpt_on_ssd: bool,
        weights: &[u64],
    ) -> Vec<u64> {
        self.planned_read_bytes(opt_on_ssd, ckpt_on_ssd, weights)
    }

    // ---- encoded-byte closed forms (the runtime's `--precision` mirror) --

    /// Elements in one (layer, micro-batch) checkpoint object (B·T·D) —
    /// the runtime stores f32 element streams; the codec layer then
    /// encodes them at the policy's checkpoint width.
    fn ckpt_elems(&self) -> u64 {
        self.model.ckpt_elems(self.micro_batch, self.seq_len)
    }

    /// The m+v moment bytes the runtime store holds per shard under a
    /// precision policy — [`Workload::runtime_moment_bytes`] generalized to
    /// the policy's optimizer codec width (4 B/elem under every shipped
    /// policy: Adam moments stay f32).
    pub fn runtime_moment_bytes_enc(&self, policy: &crate::memory::codec::PrecisionPolicy) -> u64 {
        2 * self.model.n_layers * self.model.params_per_layer()
            * policy.optimizer.bytes_per_elem()
            / self.shards
    }

    /// ENCODED bytes the runtime's `TensorStore` reads per steady-state
    /// iteration under `policy` — the exact `StepStats::ssd_bytes_read` /
    /// store `bytes_read` mirror: moments round-trip at the optimizer
    /// codec's width, checkpoints read back once at the checkpoint codec's
    /// width. At [`PrecisionPolicy::STRICT_F32`](crate::memory::codec) the
    /// checkpoint term is 2× the legacy (paper-width) `m·cs` form; under
    /// `mixed:*` it equals it exactly — the end-to-end byte halving.
    pub fn store_read_bytes_enc(
        &self,
        opt_on_ssd: bool,
        ckpt_on_ssd: bool,
        policy: &crate::memory::codec::PrecisionPolicy,
    ) -> u64 {
        self.store_working_set_bytes_enc(opt_on_ssd, ckpt_on_ssd, policy)
    }

    /// ENCODED bytes written per steady-state iteration (same symmetry as
    /// the legacy form: moments written back, checkpoints stored once).
    pub fn store_write_bytes_enc(
        &self,
        opt_on_ssd: bool,
        ckpt_on_ssd: bool,
        policy: &crate::memory::codec::PrecisionPolicy,
    ) -> u64 {
        self.store_read_bytes_enc(opt_on_ssd, ckpt_on_ssd, policy)
    }

    /// The runtime store's ENCODED working set under `policy`: all live
    /// moment objects plus the peak live checkpoint set, each at its
    /// codec's width — what a DRAM cache (whose capacity accounting is
    /// also in encoded bytes) must hold to absorb the repeat traffic.
    pub fn store_working_set_bytes_enc(
        &self,
        opt_on_ssd: bool,
        ckpt_on_ssd: bool,
        policy: &crate::memory::codec::PrecisionPolicy,
    ) -> u64 {
        let ckpt = self.m * self.model.n_layers * self.ckpt_elems()
            * policy.checkpoints.bytes_per_elem();
        (if opt_on_ssd { self.runtime_moment_bytes_enc(policy) } else { 0 })
            + (if ckpt_on_ssd { ckpt } else { 0 })
    }

    /// Residual ENCODED SSD reads under a DRAM cache — the same
    /// fit-or-nothing law as [`Workload::cached_store_read_bytes`], on the
    /// encoded working set: a half-precision store can fit (and read 0
    /// SSD bytes) in a cache its strict-f32 twin overflows.
    pub fn cached_store_read_bytes_enc(
        &self,
        opt_on_ssd: bool,
        ckpt_on_ssd: bool,
        policy: &crate::memory::codec::PrecisionPolicy,
        cache_bytes: u64,
    ) -> u64 {
        let ws = self.store_working_set_bytes_enc(opt_on_ssd, ckpt_on_ssd, policy);
        if self.cache_absorbs(ws, cache_bytes) {
            0
        } else {
            self.store_read_bytes_enc(opt_on_ssd, ckpt_on_ssd, policy)
        }
    }

    /// §3.2 — single forward-backward pass (Ratel-style) at batch size
    /// `batch = B·M` with `extra_ckpt` doubling checkpoint frequency
    /// (attention/FFN boundary checkpoints).
    ///
    /// One pass: parameters twice (fwd + recompute), checkpoints once each
    /// way — but checkpoint *size* scales with the single-pass batch.
    pub fn single_pass(&self, extra_ckpt: bool) -> Traffic {
        let batch = self.micro_batch * self.m;
        let ckpt_mult = if extra_ckpt { 2 } else { 1 };
        let cs = self.model.n_layers
            * self.model.ckpt_bytes_lp(batch, self.seq_len)
            * ckpt_mult;
        Traffic {
            param_load: 2 * self.ms_lp(),
            ckpt_load: cs,
            grad_load: 0,
            ckpt_store: cs,
            grad_store: self.grad_fp(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelcfg::{GPT_65B, SEQ_LEN};

    fn wl(m: u64) -> Workload {
        Workload { model: GPT_65B, micro_batch: 8, seq_len: SEQ_LEN, m, shards: 1 }
    }

    #[test]
    fn horizontal_matches_paper_formulas() {
        let w = wl(4);
        let t = w.horizontal();
        assert_eq!(t.param_load, 2 * 4 * w.ms_lp());
        assert_eq!(t.ckpt_load + t.ckpt_store, 2 * 4 * w.cs());
        // (2M-1)·grad_fp total gradient movement
        assert_eq!(t.grad_load + t.grad_store, (2 * 4 - 1) * w.grad_fp());
    }

    #[test]
    fn vertical_param_traffic_independent_of_m() {
        assert_eq!(wl(2).vertical().param_load, wl(16).vertical().param_load);
        assert_eq!(wl(16).vertical().param_load, 2 * wl(16).ms_lp());
    }

    #[test]
    fn vertical_beats_horizontal_for_large_models() {
        // §3.4: for GPT-65B the layer is ~6× the checkpoint, so vertical's
        // extra checkpoint traffic is far cheaper than horizontal's
        // repeated parameter loads.
        for m in [2, 4, 8, 16] {
            let w = wl(m);
            let h = w.horizontal();
            let v = w.vertical();
            assert!(
                v.total() < h.total(),
                "m={m}: vertical {} >= horizontal {}",
                v.total(),
                h.total()
            );
        }
    }

    #[test]
    fn fig5_reduction_grows_with_m() {
        let r4 = wl(4).horizontal().total() as f64 / wl(4).vertical().total() as f64;
        let r16 = wl(16).horizontal().total() as f64 / wl(16).vertical().total() as f64;
        assert!(r16 > r4, "reduction must grow with micro-batch count");
        assert!(r4 > 1.5, "m=4 reduction {r4}");
    }

    #[test]
    fn single_pass_extra_ckpt_triples_ckpt_traffic_at_1_5x_batch() {
        // §3.2's arithmetic: 2× checkpoints × 1.5× batch = 3× traffic.
        let base = wl(2); // batch 16
        let bigger = Workload { m: 3, ..base }; // batch 24 = 1.5×
        let t_base = base.single_pass(false);
        let t_big = bigger.single_pass(true);
        let ratio = t_big.ckpt_load as f64 / t_base.ckpt_load as f64;
        assert!((ratio - 3.0).abs() < 1e-9, "{ratio}");
    }

    #[test]
    fn sharding_divides_param_and_grad_traffic() {
        let w1 = wl(4);
        let w4 = Workload { shards: 4, ..w1 };
        assert_eq!(w4.horizontal().param_load * 4, w1.horizontal().param_load);
        assert_eq!(w4.vertical().grad_store * 4, w1.vertical().grad_store);
        // checkpoints are per-GPU data-parallel state: unchanged.
        assert_eq!(w4.vertical().ckpt_store, w1.vertical().ckpt_store);
    }

    #[test]
    fn chunked_limits_equal_vertical_and_horizontal() {
        for m in [1, 2, 5, 16] {
            let w = wl(m);
            assert_eq!(w.chunked_vertical(m), w.vertical(), "m={m}");
            assert_eq!(w.chunked_vertical(m + 7), w.vertical(), "m={m} oversize group");
            assert_eq!(w.chunked_vertical(1), w.horizontal(), "m={m}");
        }
    }

    #[test]
    fn serve_forms_are_the_forward_leg_of_the_schedule_forms() {
        for m in [1, 2, 5, 16] {
            let w = wl(m);
            for g in 1..=m + 3 {
                // Forward-only decode streams each resident layer ONCE —
                // exactly half the round-trip param_load of the matching
                // training schedule.
                assert_eq!(
                    2 * w.serve_param_read_bytes(g),
                    w.chunked_vertical(g).param_load,
                    "m={m} g={g}"
                );
                assert_eq!(
                    w.serve_param_read_bytes(g),
                    w.serve_param_loads(g) * w.ms_lp() / w.model.n_layers,
                    "m={m} g={g}: bytes = loads × per-layer bytes"
                );
            }
            assert_eq!(2 * w.serve_param_read_bytes(m + 7), w.vertical().param_load);
            assert_eq!(2 * w.serve_param_read_bytes(1), w.horizontal().param_load);
        }
    }

    #[test]
    fn serve_adapter_and_working_set_forms() {
        let w = wl(4);
        // Adapters are 1/denom of the parameter stream they ride.
        assert_eq!(w.serve_adapter_read_bytes(4, 64), w.serve_param_read_bytes(4) / 64);
        // Working set: one shared base + T per-tenant adapter sets — base
        // bytes do NOT scale with T (the multi-tenant sharing law).
        let ws1 = w.serve_working_set_bytes(1, 64);
        let ws4 = w.serve_working_set_bytes(4, 64);
        assert_eq!(ws4 - ws1, 3 * (w.ms_lp() / 64));
        assert!(ws4 < 2 * w.ms_lp(), "4 tenants must cost far less than 4 base images");
        // Degenerate denominators clamp instead of dividing by zero.
        assert_eq!(w.serve_param_loads(0), w.serve_param_loads(1));
        assert_eq!(w.serve_adapter_read_bytes(4, 0), w.serve_param_read_bytes(4));
    }

    /// The satellite ordering property: bytes read off the host/SSD tier
    /// satisfy vertical ≤ chunked ≤ horizontal, strictly for 1 < G < M.
    #[test]
    fn chunked_reads_between_vertical_and_horizontal() {
        let w = wl(16);
        let v = w.vertical().total_load();
        let h = w.horizontal().total_load();
        let mut prev = h;
        for g in [2u64, 4, 8] {
            let c = w.chunked_vertical(g).total_load();
            assert!(v < c && c < h, "g={g}: {v} < {c} < {h}");
            assert!(c < prev, "loads must shrink as the chunk grows: g={g}");
            prev = c;
        }
        // totals order the same way for transformer-scale layer/ckpt ratios
        let c2 = w.chunked_vertical(2).total();
        assert!(w.vertical().total() < c2 && c2 < w.horizontal().total());
    }

    /// Data-parallel closed forms: W = 1 collapses exactly to the
    /// single-worker formulas; shares cover M; vertical parameter traffic
    /// scales with the number of ACTIVE workers while horizontal's total is
    /// W-invariant (it already reloads per micro-batch).
    #[test]
    fn dp_forms_collapse_and_scale() {
        let w = wl(16);
        assert_eq!(w.vertical_dp(1), w.vertical());
        assert_eq!(w.horizontal_dp(1), w.horizontal());
        assert_eq!(w.chunked_vertical_dp(2, 1), w.chunked_vertical(2));
        for workers in [2u64, 3, 4, 16, 20] {
            let shares = w.dp_shares(workers);
            assert_eq!(shares.iter().sum::<u64>(), w.m, "W={workers}");
            let active = shares.len() as u64;
            assert_eq!(
                w.vertical_dp(workers).param_load,
                active * 2 * w.ms_lp(),
                "W={workers}"
            );
            assert_eq!(w.vertical_dp(workers).grad_store, active * w.grad_fp());
            assert_eq!(w.horizontal_dp(workers).param_load, w.horizontal().param_load);
        }
    }

    /// The shared-tier pressure the fig12 bench measures: total vertical
    /// SSD/host loads grow with W (every replica re-reads the model), and
    /// the all-reduce formula matches 2(W−1)/W.
    #[test]
    fn dp_vertical_loads_grow_with_workers() {
        let w = wl(16);
        let mut prev = w.vertical_dp(1).total_load();
        for workers in [2u64, 4, 8] {
            let cur = w.vertical_dp(workers).total_load();
            assert!(cur > prev, "W={workers}: {cur} <= {prev}");
            prev = cur;
        }
        assert_eq!(w.allreduce_bytes_per_worker(1), 0);
        assert_eq!(w.allreduce_bytes_per_worker(2), w.grad_fp());
        assert_eq!(
            w.allreduce_bytes_per_worker(4),
            (2 * 3 * w.grad_fp()).div_ceil(4)
        );
    }

    /// The satellite consistency fix: the closed form counts the same
    /// EFFECTIVE workers the runtime engine does, so when W > M the idle
    /// ranks move nothing — and per-worker × active covers the total with
    /// less than one worker's slack (same rounding everywhere).
    #[test]
    fn allreduce_counts_effective_workers_like_the_runtime() {
        use crate::coordinator::dist::ring_traffic_bytes;
        for m in [1u64, 2, 3, 5, 16] {
            let w = Workload { m, ..wl(1) };
            for workers in 1..=8u64 {
                let active = w.effective_workers(workers);
                assert_eq!(active, workers.min(m), "m={m} W={workers}");
                // the closed-form total IS the runtime's accounting
                assert_eq!(
                    w.allreduce_bytes_total(workers),
                    ring_traffic_bytes(active as usize, w.grad_fp()),
                    "m={m} W={workers}"
                );
                let per = w.allreduce_bytes_per_worker(workers);
                let total = w.allreduce_bytes_total(workers);
                assert!(per * active >= total, "m={m} W={workers}");
                assert!(per * active < total + active, "m={m} W={workers}");
                // W > M: only M ranks ring; W = 1 rings nothing
                if workers > m {
                    assert_eq!(total, ring_traffic_bytes(m as usize, w.grad_fp()));
                }
            }
        }
    }

    /// Sharded (ZeRO-style) closed forms: reduce-scatter + all-gather over
    /// the whole group, and per-rank optimizer SSD round trips ~1/W of the
    /// rank-0 path's.
    #[test]
    fn sharded_forms_scale_with_group() {
        let w = wl(16);
        // rs + ag of the SAME payload would equal the all-reduce; here the
        // gather moves params (lp), the scatter grads (fp32)
        assert_eq!(w.reduce_scatter_bytes_total(1), 0);
        assert_eq!(w.allgather_bytes_total(1), 0);
        assert_eq!(w.reduce_scatter_bytes_total(4), 3 * w.grad_fp());
        assert_eq!(w.allgather_bytes_total(4), 3 * w.ms_lp());
        assert_eq!(
            w.reduce_scatter_bytes_per_worker(4),
            (3 * w.grad_fp()).div_ceil(4)
        );
        // per-rank optimizer SSD round trip shrinks ~1/W
        let full = w.opt_ssd_round_trip_bytes();
        assert_eq!(full, 2 * w.opt_state_bytes());
        assert_eq!(w.sharded_opt_ssd_bytes_per_rank(1), full);
        for workers in [2u64, 4, 8] {
            let per = w.sharded_opt_ssd_bytes_per_rank(workers);
            assert_eq!(per, full.div_ceil(workers), "W={workers}");
            assert!(per * workers >= full && per * workers < full + workers);
        }
        // the group (not the active count) sizes the sharded rings: W=8
        // ranks all hold shards even when m < W
        let small = Workload { m: 2, ..w };
        assert_eq!(small.reduce_scatter_bytes_total(8), 7 * small.grad_fp());
        assert_eq!(small.allreduce_bytes_total(8), 2 * small.grad_fp());
    }

    /// `--param-persist` closed forms: master params are f32 (= grad_fp),
    /// the round trip reads + writes every byte once, and sharding divides
    /// the per-rank round trip ~1/W (ceil) — the law fig17_elastic pins
    /// against the runtime's per-rank `ParamShardCounters`.
    #[test]
    fn param_persist_round_trip_scales_inverse_w() {
        let w = wl(16);
        assert_eq!(w.param_state_bytes(), w.grad_fp());
        let full = w.param_ssd_round_trip_bytes();
        assert_eq!(full, 2 * w.param_state_bytes());
        assert_eq!(w.sharded_param_ssd_bytes_per_rank(1), full);
        for workers in [2u64, 3, 4, 8] {
            let per = w.sharded_param_ssd_bytes_per_rank(workers);
            assert_eq!(per, full.div_ceil(workers), "W={workers}");
            // ceil never under-counts and over-counts by < W bytes total
            assert!(per * workers >= full && per * workers < full + workers);
        }
        // model-parallel shards divide the persisted parameter state too
        let w4 = Workload { shards: 4, ..w };
        assert_eq!(w4.param_state_bytes() * 4, w.param_state_bytes());
    }

    /// The DRAM cache tier's fit-or-nothing law and its working-set
    /// arithmetic (shared with `sim::simulate_store` and the runtime
    /// `CachedStore`).
    #[test]
    fn cache_absorption_is_fit_or_nothing() {
        let w = wl(4);
        let ws = w.ssd_working_set_bytes(0.0, 0.0, 0.0);
        assert_eq!(ws, w.ms_lp() + 4 * w.cs() + w.opt_state_bytes());
        assert!(w.cache_absorbs(ws, ws));
        assert!(w.cache_absorbs(ws, ws + 1));
        assert!(!w.cache_absorbs(ws, ws - 1));
        assert!(!w.cache_absorbs(0, 1 << 40), "an empty set has nothing to absorb");
        // CPU placement shrinks the SSD-resident working set
        let half = w.ssd_working_set_bytes(0.5, 1.0, 1.0);
        assert_eq!(half, w.ms_lp() / 2);
        assert_eq!(w.ssd_working_set_bytes(1.0, 1.0, 1.0), 0);
    }

    /// The runtime-store closed forms mirror the `TensorStore` counters:
    /// moments are TWO fp32 streams (m, v — master params stay host
    /// resident), checkpoints round-trip once per (layer, micro-batch), and
    /// the cached residual is zero exactly when the working set fits.
    #[test]
    fn runtime_store_forms_mirror_the_counters() {
        let w = wl(4);
        assert_eq!(
            w.runtime_moment_bytes(),
            2 * GPT_65B.n_layers * GPT_65B.params_per_layer() * 4
        );
        assert_eq!(w.store_read_bytes(true, false), w.runtime_moment_bytes());
        assert_eq!(w.store_read_bytes(false, true), 4 * w.cs());
        assert_eq!(
            w.store_read_bytes(true, true),
            w.store_write_bytes(true, true),
            "the store's read/write traffic is symmetric"
        );
        assert_eq!(w.store_read_bytes(false, false), 0);
        let ws = w.store_working_set_bytes(true, true);
        assert_eq!(w.cached_store_read_bytes(true, true, ws), 0);
        assert_eq!(
            w.cached_store_read_bytes(true, true, ws - 1),
            w.store_read_bytes(true, true),
            "a cache one byte short absorbs nothing (LRU cyclic sweep)"
        );
    }

    /// The encoded-byte family: strict f32 stores checkpoints at 4 B/elem
    /// (2× the paper's lp units), `mixed:f16` halves that back to the
    /// paper width exactly, moments stay f32 under every shipped policy,
    /// and read/write symmetry carries over.
    #[test]
    fn encoded_forms_follow_the_precision_policy() {
        use crate::memory::codec::{Precision, PrecisionPolicy};
        let w = wl(4);
        let strict = PrecisionPolicy::STRICT_F32;
        let f16 = Precision::MixedF16.policy();
        let bf16 = Precision::MixedBf16.policy();
        for p in [&strict, &f16, &bf16] {
            assert_eq!(w.runtime_moment_bytes_enc(p), w.runtime_moment_bytes());
            assert_eq!(
                w.store_read_bytes_enc(true, true, p),
                w.store_write_bytes_enc(true, true, p),
                "encoded store traffic stays read/write symmetric"
            );
            assert_eq!(w.store_read_bytes_enc(false, false, p), 0);
        }
        // strict f32: moments match the legacy form, checkpoints are 2×
        // the legacy lp-unit term (4 B/elem vs BYTES_LP = 2)
        assert_eq!(
            w.store_read_bytes_enc(true, false, &strict),
            w.store_read_bytes(true, false)
        );
        assert_eq!(w.store_read_bytes_enc(false, true, &strict), 2 * 4 * w.cs());
        // mixed halves the checkpoint stream end-to-end: exactly the paper
        // width, i.e. exactly 0.5× the strict-f32 encoded bytes
        for p in [&f16, &bf16] {
            assert_eq!(w.store_read_bytes_enc(false, true, p), 4 * w.cs());
            assert_eq!(
                2 * w.store_read_bytes_enc(false, true, p),
                w.store_read_bytes_enc(false, true, &strict)
            );
        }
        // working set == per-iteration reads (every live byte read once)
        assert_eq!(
            w.store_working_set_bytes_enc(true, true, &f16),
            w.store_read_bytes_enc(true, true, &f16)
        );
    }

    /// The encoded cache law: a cache sized to the mixed working set
    /// absorbs everything under `mixed:f16` and nothing under strict f32.
    #[test]
    fn encoded_cache_fit_is_per_policy() {
        use crate::memory::codec::{Precision, PrecisionPolicy};
        let w = wl(4);
        let strict = PrecisionPolicy::STRICT_F32;
        let f16 = Precision::MixedF16.policy();
        let ws_mixed = w.store_working_set_bytes_enc(true, true, &f16);
        assert_eq!(w.cached_store_read_bytes_enc(true, true, &f16, ws_mixed), 0);
        assert_eq!(
            w.cached_store_read_bytes_enc(true, true, &strict, ws_mixed),
            w.store_read_bytes_enc(true, true, &strict),
            "the f32 twin overflows the same cache and absorbs nothing"
        );
    }

    /// The multi-path planner closed forms: per-path entries conserve the
    /// aggregate store traffic object-for-object, split proportionally to
    /// the path weights, and degenerate to the aggregate on one path.
    #[test]
    fn planned_forms_conserve_and_split_by_weight() {
        let w = wl(4);
        // one path gets everything — exactly the aggregate closed form
        assert_eq!(
            w.planned_read_bytes(true, true, &[7]),
            vec![w.store_read_bytes(true, true)]
        );
        // three weighted paths: conservation is exact (no rounding slack)
        for (opt, ckpt) in [(true, true), (true, false), (false, true), (false, false)] {
            let per = w.planned_read_bytes(opt, ckpt, &[30, 10, 10]);
            assert_eq!(per.len(), 3);
            assert_eq!(per.iter().sum::<u64>(), w.store_read_bytes(opt, ckpt));
            assert_eq!(per, w.planned_write_bytes(opt, ckpt, &[30, 10, 10]));
        }
        // proportionality: a 3:1:1 weighting puts ~3/5 on the fast path
        let per = w.planned_read_bytes(true, true, &[30, 10, 10]);
        let total = w.store_read_bytes(true, true) as f64;
        let frac = per[0] as f64 / total;
        assert!((frac - 0.6).abs() < 0.01, "fast-path share {frac}");
        // a zero-weight path moves nothing
        let per = w.planned_read_bytes(true, true, &[0, 1, 1]);
        assert_eq!(per[0], 0);
        assert_eq!(per[1] + per[2], w.store_read_bytes(true, true));
    }

    /// The closed form applies the RUNTIME's extent arithmetic, not its own
    /// rounding: summing `plan_shares` over the object list reproduces the
    /// per-path entries exactly.
    #[test]
    fn planned_forms_match_plan_shares_per_object() {
        use crate::memory::plan_shares;
        let w = wl(3);
        let weights = [13u64, 5, 3];
        let mut expect = vec![0u64; 3];
        let moment = GPT_65B.params_per_layer() * BYTES_FP;
        for (count, bytes) in
            [(2 * GPT_65B.n_layers, moment), (3 * GPT_65B.n_layers, w.ckpt_layer())]
        {
            for (acc, s) in expect.iter_mut().zip(plan_shares(bytes, &weights)) {
                *acc += count * s;
            }
        }
        assert_eq!(w.planned_read_bytes(true, true, &weights), expect);
    }

    #[test]
    fn m_equals_1_degenerates_gracefully() {
        let w = wl(1);
        let h = w.horizontal();
        let v = w.vertical();
        assert_eq!(h.grad_load, 0);
        assert_eq!(v.ckpt_load, w.cs()); // only bwd recompute reads
        assert_eq!(h.param_load, 2 * w.ms_lp());
    }
}
