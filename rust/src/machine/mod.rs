//! Machine specifications (paper Table 1) — capacities, link bandwidths, and
//! sustained compute rates that parameterize the roofline, the performance
//! model, the LP, and the discrete-event simulator.
//!
//! Compute rates are *sustained* training TFLOPs (not peak datasheet
//! numbers): the paper reports 63.1 TFLOPs/GPU for the A5000 cluster and
//! 128.3 for A100 when fully compute-bound, so those anchor the compute
//! roofline for each machine.

/// One evaluation machine.
#[derive(Clone, Copy, Debug)]
pub struct Machine {
    pub name: &'static str,
    /// GPU memory per device, bytes.
    pub gpu_mem: u64,
    /// Usable CPU DRAM, bytes.
    pub cpu_mem: u64,
    /// Host→device and device→host bandwidth (PCIe Gen4 x16 effective).
    pub pcie_bw: f64,
    /// Inter-GPU interconnect bandwidth per GPU (NVLink, or PCIe P2P on
    /// boards without it) — the link the ring collective legs ride in the
    /// multi-worker simulator, distinct from the host PCIe lanes.
    pub link_bw: f64,
    /// SSD read / write bandwidth, bytes/s.
    pub ssd_read_bw: f64,
    pub ssd_write_bw: f64,
    /// Sustained GPU compute for transformer training, FLOP/s per GPU.
    pub gpu_flops: f64,
    /// Sustained CPU optimizer-step rate, parameter elements/s
    /// (fused AVX Adam over DDR4: memory-bound at ~4 state streams).
    pub cpu_adam_elems_per_s: f64,
}

/// Machine 1 — A5000 node (Table 1): 24 GB GPU, 256 GB DDR4, PM9A3 NVMe.
pub const MACHINE1_A5000: Machine = Machine {
    name: "A5000-node",
    gpu_mem: 24 * GIB,
    cpu_mem: 256 * GIB,
    pcie_bw: 24.0e9,
    link_bw: 20.0e9, // no NVLink: P2P rides PCIe Gen4
    ssd_read_bw: 6.5e9,  // PM9A3 seq read
    ssd_write_bw: 3.5e9, // PM9A3 seq write
    gpu_flops: 65.0e12,  // sustained bf16 training (≈70% of 91.1 peak... anchored to §6.2)
    cpu_adam_elems_per_s: 1.5e9,
};

/// Machine 2 — A100 node (Table 1): 40 GB GPU, 400 GB DDR4, 4 TB cloud SSD.
pub const MACHINE2_A100: Machine = Machine {
    name: "A100-node",
    gpu_mem: 40 * GIB,
    cpu_mem: 400 * GIB,
    pcie_bw: 24.0e9,
    link_bw: 150.0e9, // NVLink3 effective per-GPU collective bandwidth
    ssd_read_bw: 3.2e9,  // shared cloud storage (paper notes contention)
    ssd_write_bw: 2.8e9,
    gpu_flops: 135.0e12, // sustained bf16 training on A100-40GB
    cpu_adam_elems_per_s: 2.5e9,
};

pub const GIB: u64 = 1 << 30;

impl Machine {
    /// Reserve a fraction of CPU DRAM for the OS/allocator; the LP's
    /// `usable_dram` (Algorithm 1).
    pub fn usable_dram(&self) -> u64 {
        (self.cpu_mem as f64 * 0.90) as u64
    }

    /// Usable GPU memory after framework/workspace reservation.
    pub fn usable_gpu(&self) -> u64 {
        (self.gpu_mem as f64 * 0.92) as u64
    }

    /// Scale to an n-GPU data-parallel node: per-GPU bandwidths shrink
    /// because PCIe lanes and the SSD are shared.
    pub fn with_gpus(&self, n_gpus: u64) -> NodeSpec {
        NodeSpec { machine: *self, n_gpus }
    }
}

/// A (machine, #GPUs) evaluation point.
#[derive(Clone, Copy, Debug)]
pub struct NodeSpec {
    pub machine: Machine,
    pub n_gpus: u64,
}

impl NodeSpec {
    /// Aggregate GPU compute.
    pub fn total_flops(&self) -> f64 {
        self.machine.gpu_flops * self.n_gpus as f64
    }

    /// Host↔device bandwidth available to EACH GPU. Dual-socket boards give
    /// every GPU its own Gen4 x16 link up to 4 GPUs, so per-GPU bandwidth is
    /// flat but the *host-side* aggregate contends with SSD DMA (modeled in
    /// the simulator, not here).
    pub fn pcie_bw_per_gpu(&self) -> f64 {
        self.machine.pcie_bw
    }

    /// Inter-GPU interconnect bandwidth per GPU — the ring-collective legs'
    /// resource in the multi-worker simulator (NVLink, or PCIe P2P where
    /// there is none). Independent of the host PCIe lanes.
    pub fn link_bw_per_gpu(&self) -> f64 {
        self.machine.link_bw
    }

    /// SSD bandwidth is a single shared resource across GPUs.
    pub fn ssd_read_bw(&self) -> f64 {
        self.machine.ssd_read_bw
    }

    pub fn ssd_write_bw(&self) -> f64 {
        self.machine.ssd_write_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacities_match_table1() {
        assert_eq!(MACHINE1_A5000.gpu_mem, 24 * GIB);
        assert_eq!(MACHINE2_A100.gpu_mem, 40 * GIB);
        assert_eq!(MACHINE1_A5000.cpu_mem, 256 * GIB);
        assert_eq!(MACHINE2_A100.cpu_mem, 400 * GIB);
    }

    #[test]
    fn usable_fractions_below_capacity() {
        for m in [MACHINE1_A5000, MACHINE2_A100] {
            assert!(m.usable_dram() < m.cpu_mem);
            assert!(m.usable_gpu() < m.gpu_mem);
        }
    }

    #[test]
    fn node_spec_aggregates() {
        let node = MACHINE2_A100.with_gpus(4);
        assert!((node.total_flops() - 4.0 * MACHINE2_A100.gpu_flops).abs() < 1.0);
        assert_eq!(node.ssd_read_bw(), MACHINE2_A100.ssd_read_bw);
    }

    #[test]
    fn link_bandwidths_are_sane() {
        // NVLink beats PCIe on the A100 node; the A5000 node's P2P link is
        // PCIe-class (no NVLink), and both comfortably beat the SSD.
        assert!(MACHINE2_A100.link_bw > MACHINE2_A100.pcie_bw);
        assert!(MACHINE1_A5000.link_bw <= MACHINE1_A5000.pcie_bw);
        for m in [MACHINE1_A5000, MACHINE2_A100] {
            assert!(m.link_bw > m.ssd_read_bw);
            assert_eq!(m.with_gpus(2).link_bw_per_gpu(), m.link_bw);
        }
    }

    #[test]
    fn ssd_is_orders_below_pcie() {
        // The premise of the whole paper: host–SSD bandwidth is the scarce
        // resource, a few GB/s vs tens for PCIe.
        for m in [MACHINE1_A5000, MACHINE2_A100] {
            assert!(m.ssd_read_bw < m.pcie_bw / 2.0);
        }
    }
}
