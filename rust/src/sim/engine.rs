//! Virtual-time list-scheduling engine.
//!
//! Operations declare a resource, a duration, and dependencies. Each
//! resource serves ops one at a time in ready order (FIFO by the moment all
//! dependencies complete, ties by submission order) — the same semantics as
//! [`crate::exec::LaneExecutor`], but in virtual time, so a multi-hour
//! GPT-175B iteration simulates in microseconds.

use std::collections::BinaryHeap;

/// Resource (lane) identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Resource(pub usize);

/// One operation in the schedule DAG.
#[derive(Clone, Debug)]
pub struct SimOp {
    pub resource: Resource,
    pub duration: f64,
    pub deps: Vec<usize>,
    /// Optional label for per-category accounting.
    pub tag: u32,
}

/// The simulator: build ops, then `run`.
#[derive(Default)]
pub struct DiscreteSim {
    n_resources: usize,
    ops: Vec<SimOp>,
}

/// Outcome of a simulation run.
#[derive(Clone, Debug)]
pub struct RunStats {
    /// Completion time of the whole DAG.
    pub makespan: f64,
    /// Per-op completion times.
    pub finish: Vec<f64>,
    /// Per-resource busy time (utilization = busy / makespan).
    pub busy: Vec<f64>,
}

#[derive(PartialEq)]
struct Ready {
    time: f64,
    seq: usize,
    op: usize,
}

impl Eq for Ready {}

impl Ord for Ready {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap: earlier ready time first, then submission order
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Ready {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl DiscreteSim {
    pub fn new(n_resources: usize) -> Self {
        DiscreteSim { n_resources, ops: Vec::new() }
    }

    /// Add an op; returns its id for use as a dependency.
    pub fn op(&mut self, resource: Resource, duration: f64, deps: &[usize]) -> usize {
        self.op_tagged(resource, duration, deps, 0)
    }

    pub fn op_tagged(
        &mut self,
        resource: Resource,
        duration: f64,
        deps: &[usize],
        tag: u32,
    ) -> usize {
        assert!(resource.0 < self.n_resources, "unknown resource");
        assert!(duration >= 0.0, "negative duration");
        for &d in deps {
            assert!(d < self.ops.len(), "forward dependency {d}");
        }
        self.ops.push(SimOp { resource, duration, deps: deps.to_vec(), tag });
        self.ops.len() - 1
    }

    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// Execute in virtual time.
    pub fn run(&self) -> RunStats {
        let n = self.ops.len();
        let mut remaining: Vec<usize> = self.ops.iter().map(|o| o.deps.len()).collect();
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, op) in self.ops.iter().enumerate() {
            for &d in &op.deps {
                dependents[d].push(i);
            }
        }
        // One ready-queue per resource; events drive time forward.
        let mut queues: Vec<BinaryHeap<Ready>> = (0..self.n_resources)
            .map(|_| BinaryHeap::new())
            .collect();
        let mut res_free = vec![0.0_f64; self.n_resources];
        let mut busy = vec![0.0_f64; self.n_resources];
        let mut finish = vec![f64::NAN; n];
        let mut done = 0usize;

        for (i, op) in self.ops.iter().enumerate() {
            if op.deps.is_empty() {
                queues[op.resource.0].push(Ready { time: 0.0, seq: i, op: i });
            }
        }

        // Global event loop: repeatedly pick the resource/op pair that can
        // start earliest. With FIFO-in-ready-order per resource this is
        // equivalent to discrete-event simulation of the lanes.
        while done < n {
            // find the resource whose head op starts earliest
            let mut best: Option<(f64, usize)> = None; // (start_time, resource)
            for (r, q) in queues.iter().enumerate() {
                if let Some(head) = q.peek() {
                    let start = head.time.max(res_free[r]);
                    if best.is_none_or(|(s, _)| start < s) {
                        best = Some((start, r));
                    }
                }
            }
            let Some((start, r)) = best else {
                panic!("deadlock: {} of {} ops completed (cyclic deps?)", done, n);
            };
            let Ready { op, .. } = queues[r].pop().unwrap();
            let end = start + self.ops[op].duration;
            res_free[r] = end;
            busy[r] += self.ops[op].duration;
            finish[op] = end;
            done += 1;
            for &dep in &dependents[op] {
                remaining[dep] -= 1;
                if remaining[dep] == 0 {
                    let ready_time = self.ops[dep]
                        .deps
                        .iter()
                        .map(|&d| finish[d])
                        .fold(0.0_f64, f64::max);
                    queues[self.ops[dep].resource.0].push(Ready {
                        time: ready_time,
                        seq: dep,
                        op: dep,
                    });
                }
            }
        }

        let makespan = finish.iter().copied().fold(0.0_f64, f64::max);
        RunStats { makespan, finish, busy }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const R0: Resource = Resource(0);
    const R1: Resource = Resource(1);

    #[test]
    fn serial_chain_sums() {
        let mut s = DiscreteSim::new(1);
        let a = s.op(R0, 1.0, &[]);
        let b = s.op(R0, 2.0, &[a]);
        let _c = s.op(R0, 3.0, &[b]);
        assert!((s.run().makespan - 6.0).abs() < 1e-12);
    }

    #[test]
    fn parallel_resources_overlap() {
        let mut s = DiscreteSim::new(2);
        s.op(R0, 5.0, &[]);
        s.op(R1, 3.0, &[]);
        assert!((s.run().makespan - 5.0).abs() < 1e-12);
    }

    #[test]
    fn same_resource_serializes() {
        let mut s = DiscreteSim::new(1);
        s.op(R0, 5.0, &[]);
        s.op(R0, 3.0, &[]);
        assert!((s.run().makespan - 8.0).abs() < 1e-12);
    }

    #[test]
    fn dependency_cross_resource() {
        let mut s = DiscreteSim::new(2);
        let a = s.op(R0, 2.0, &[]);
        let b = s.op(R1, 1.0, &[a]);
        let st = s.run();
        assert!((st.finish[b] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn diamond_joins_at_max() {
        let mut s = DiscreteSim::new(3);
        let root = s.op(R0, 1.0, &[]);
        let left = s.op(R1, 5.0, &[root]);
        let right = s.op(R2(), 2.0, &[root]);
        let join = s.op(R0, 1.0, &[left, right]);
        let st = s.run();
        assert!((st.finish[join] - 7.0).abs() < 1e-12);
    }

    fn R2() -> Resource {
        Resource(2)
    }

    #[test]
    fn pipeline_steady_state_throughput() {
        // Two-stage pipeline, stage times 1 and 2: K items finish at
        // ≈ 1 + 2K (bound by the slower stage).
        let mut s = DiscreteSim::new(2);
        let k = 50;
        let mut prev_a = None;
        for _ in 0..k {
            let a = s.op(R0, 1.0, &prev_a.map(|p| vec![p]).unwrap_or_default());
            let _b = s.op(R1, 2.0, &[a]);
            prev_a = Some(a);
        }
        let st = s.run();
        assert!((st.makespan - (1.0 + 2.0 * k as f64)).abs() < 1e-9, "{}", st.makespan);
    }

    #[test]
    fn busy_accounting() {
        let mut s = DiscreteSim::new(2);
        s.op(R0, 4.0, &[]);
        s.op(R1, 1.0, &[]);
        let st = s.run();
        assert!((st.busy[0] - 4.0).abs() < 1e-12);
        assert!((st.busy[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_duration_ops_ok() {
        let mut s = DiscreteSim::new(1);
        let a = s.op(R0, 0.0, &[]);
        let b = s.op(R0, 0.0, &[a]);
        assert_eq!(s.run().finish[b], 0.0);
    }

    #[test]
    #[should_panic(expected = "forward dependency")]
    fn forward_deps_rejected() {
        let mut s = DiscreteSim::new(1);
        s.op(R0, 1.0, &[5]);
    }

    #[test]
    fn large_dag_runs_fast() {
        let mut s = DiscreteSim::new(4);
        let mut prev: Vec<usize> = vec![];
        for layer in 0..200 {
            let mut next = vec![];
            for j in 0..8 {
                let deps: Vec<usize> = prev.clone();
                next.push(s.op(Resource((layer + j) % 4), 0.5, &deps));
            }
            prev = next;
        }
        let t0 = std::time::Instant::now();
        let st = s.run();
        assert!(st.makespan > 0.0);
        assert!(t0.elapsed().as_secs_f64() < 2.0);
    }
}
