//! Discrete-event twin of the forward-only serving engine
//! (`coordinator::serve`) plus tokens/sec closed forms.
//!
//! One decode token step = one schedule-ordered forward sweep: every layer
//! load streams the shared base image plus the tenant's adapter delta off
//! the SSD tier (SSD read → H2D upload), gated by the same `--io-depth K`
//! lookahead window as training ([`super::schedules::IoGate`]); each lane
//! visit is a GPU op depending on its layer's upload. The runtime's storage
//! knobs mirror exactly like the training sim: `ssds` stripes multiply SSD
//! read bandwidth, and the DRAM cache obeys the fit-or-nothing absorption
//! law — a serve working set ([one base image + T adapter
//! sets](crate::traffic::Workload::serve_working_set_bytes)) that fits in
//! cache is served from DRAM, so its SSD reads vanish while the H2D stream
//! remains.
//!
//! Reported throughput is steady-state (makespan of 3 token steps minus 2,
//! warm-up excluded), like every sim in this module; the
//! [`serve_token_bound`] closed form (pipelined bottleneck at depth ≥ 1,
//! serialized sum at depth 0) lower-bounds it and `benches/fig18_serve.rs`
//! sweeps the two together.

use super::engine::DiscreteSim;
use super::schedules::{IoGate, GPU, H2D, N_RESOURCES, SSD_R};

/// Everything the serve twin needs, in plain units (the runtime engine's
/// `ServeModel`/store counters map 1:1 — no `SystemParams` coupling).
#[derive(Clone, Copy, Debug)]
pub struct ServeSimConfig {
    pub n_layers: u64,
    /// Bytes one layer load streams (base + adapter at f32).
    pub layer_bytes: f64,
    /// Bytes the per-token-step embedding stream moves.
    pub embed_bytes: f64,
    /// GPU seconds per (layer, lane) visit.
    pub compute_s_per_visit: f64,
    /// Concurrent decode lanes (batch size B — the schedule grid's m).
    pub lanes: u64,
    /// Chunked grouping G: `G ≥ lanes` = vertical decode (one sweep),
    /// `G = 1` = horizontal (per-lane reload) — loads/step = N·⌈B/G⌉.
    pub group: u64,
    /// Lookahead window K (0 = synchronous loads).
    pub io_depth: usize,
    /// Striped SSD count (read bandwidth × N).
    pub ssds: u64,
    /// DRAM cache capacity; 0 disables the tier.
    pub cache_bytes: u64,
    /// The serve working set the cache must hold (shared base + T adapter
    /// sets — [`crate::traffic::Workload::serve_working_set_bytes`]).
    pub working_set_bytes: u64,
    /// Single-device SSD read bandwidth (bytes/s).
    pub ssd_read_bps: f64,
    /// Host-to-device bandwidth (bytes/s).
    pub h2d_bps: f64,
}

/// Steady-state serve throughput.
#[derive(Clone, Copy, Debug)]
pub struct ServeSimResult {
    /// Seconds per token step (all lanes advance one token).
    pub t_token_s: f64,
    /// Generated tokens/s across the batch (`lanes / t_token_s`).
    pub tokens_per_s: f64,
    /// SSD bytes read per token step (0 when the cache absorbs).
    pub ssd_read_bytes_per_token: f64,
    /// Whether the DRAM cache absorbed the parameter re-streaming.
    pub absorbed: bool,
}

/// Layer-parameter loads one token step performs: N·⌈B/G⌉ — the same count
/// as `schedule::param_loads(forward_order)` and
/// [`crate::traffic::Workload::serve_param_loads`].
pub fn serve_loads_per_token(c: &ServeSimConfig) -> u64 {
    c.n_layers * c.lanes.div_ceil(c.group.max(1))
}

/// Fit-or-nothing DRAM absorption (the `CachedStore` law: a cyclic decode
/// sweep defeats LRU unless the whole working set is resident).
pub fn serve_cache_absorbs(c: &ServeSimConfig) -> bool {
    c.cache_bytes > 0 && c.working_set_bytes <= c.cache_bytes
}

/// Closed-form steady-state bound on seconds per token step. At depth ≥ 1
/// the three resources pipeline, so a step is bound by its busiest resource;
/// at depth 0 every load serializes with its compute and the times add.
pub fn serve_token_bound(c: &ServeSimConfig) -> f64 {
    let loads = serve_loads_per_token(c) as f64;
    let read_bps = c.ssd_read_bps * c.ssds.max(1) as f64;
    let absorbed = serve_cache_absorbs(c);
    let ssd = if absorbed {
        0.0
    } else {
        (loads * c.layer_bytes + c.embed_bytes) / read_bps
    };
    let h2d = (loads * c.layer_bytes + c.embed_bytes) / c.h2d_bps;
    let gpu = (c.n_layers * c.lanes) as f64 * c.compute_s_per_visit;
    if c.io_depth == 0 {
        ssd + h2d + gpu
    } else {
        ssd.max(h2d).max(gpu)
    }
}

/// Run the discrete-event serve twin to steady state.
pub fn simulate_serve(c: &ServeSimConfig) -> ServeSimResult {
    let warm = build_and_run(c, 2);
    let full = build_and_run(c, 3);
    let t_token = (full - warm).max(1e-12);
    let absorbed = serve_cache_absorbs(c);
    let loads = serve_loads_per_token(c) as f64;
    ServeSimResult {
        t_token_s: t_token,
        tokens_per_s: c.lanes as f64 / t_token,
        ssd_read_bytes_per_token: if absorbed { 0.0 } else { loads * c.layer_bytes + c.embed_bytes },
        absorbed,
    }
}

fn build_and_run(c: &ServeSimConfig, steps: u32) -> f64 {
    let group = c.group.max(1);
    let chunks = c.lanes.div_ceil(group);
    let read_bps = c.ssd_read_bps * c.ssds.max(1) as f64;
    let absorbed = serve_cache_absorbs(c);
    let t_ssd = |bytes: f64| if absorbed { 0.0 } else { bytes / read_bps };
    let t_h2d = |bytes: f64| bytes / c.h2d_bps;

    let mut sim = DiscreteSim::new(N_RESOURCES);
    let mut gate = IoGate::new(c.io_depth);
    // chains the "previous step finished" dependency across token steps
    let mut step_tail: Vec<usize> = Vec::new();
    for _step in 0..steps {
        // embedding stream: once per token step, on the read+upload path
        let e_r = sim.op(SSD_R, t_ssd(c.embed_bytes), &step_tail);
        let mut last_compute = sim.op(H2D, t_h2d(c.embed_bytes), &[e_r]);
        for chunk in 0..chunks {
            // the last chunk may hold fewer than G lanes
            let lanes_here = group.min(c.lanes - chunk * group);
            for _l in 0..c.n_layers {
                // one layer load: SSD read then H2D, gated by the window
                let mut deps = gate.gate();
                deps.extend_from_slice(&step_tail);
                let r = sim.op(SSD_R, t_ssd(c.layer_bytes), &deps);
                let u = sim.op(H2D, t_h2d(c.layer_bytes), &[r]);
                // the chunk's lane visits: GPU serialized, fed by the upload
                for _lane in 0..lanes_here {
                    last_compute = sim.op(
                        GPU,
                        c.compute_s_per_visit,
                        &[u, last_compute],
                    );
                }
                gate.loaded(last_compute);
            }
        }
        // the runtime flushes lanes at every token-step boundary
        gate.barrier();
        step_tail = vec![last_compute];
    }
    sim.run().makespan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ServeSimConfig {
        ServeSimConfig {
            n_layers: 8,
            layer_bytes: 64e6,
            embed_bytes: 4e6,
            compute_s_per_visit: 2e-3,
            lanes: 4,
            group: u64::MAX,
            io_depth: 2,
            ssds: 1,
            cache_bytes: 0,
            working_set_bytes: 8 * 64_000_000 + 4_000_000,
            ssd_read_bps: 3e9,
            h2d_bps: 20e9,
        }
    }

    #[test]
    fn steady_state_at_least_closed_form_bound() {
        for depth in [0usize, 1, 2, 8] {
            for group in [1u64, 2, u64::MAX] {
                let c = ServeSimConfig { io_depth: depth, group, ..base() };
                let r = simulate_serve(&c);
                let bound = serve_token_bound(&c);
                assert!(
                    r.t_token_s >= bound * 0.999,
                    "depth={depth} group={group}: sim {} < bound {}",
                    r.t_token_s,
                    bound
                );
                // within 3x of the bound: the DES pipelines for real
                assert!(r.t_token_s <= bound * 3.0, "depth={depth} group={group}");
            }
        }
    }

    #[test]
    fn lookahead_overlap_beats_synchronous() {
        let sync = simulate_serve(&ServeSimConfig { io_depth: 0, ..base() });
        let over = simulate_serve(&ServeSimConfig { io_depth: 2, ..base() });
        assert!(
            over.t_token_s < sync.t_token_s * 0.95,
            "overlap {} !< sync {}",
            over.t_token_s,
            sync.t_token_s
        );
    }

    #[test]
    fn ssd_striping_scales_the_read_bottleneck() {
        let one = simulate_serve(&base());
        let four = simulate_serve(&ServeSimConfig { ssds: 4, ..base() });
        assert!(four.tokens_per_s > one.tokens_per_s * 1.5, "{} vs {}", four.tokens_per_s, one.tokens_per_s);
    }

    #[test]
    fn cache_absorption_is_fit_or_nothing() {
        let ws = base().working_set_bytes;
        let miss = simulate_serve(&ServeSimConfig { cache_bytes: ws - 1, ..base() });
        let fit = simulate_serve(&ServeSimConfig { cache_bytes: ws, ..base() });
        assert!(!miss.absorbed && miss.ssd_read_bytes_per_token > 0.0);
        assert!(fit.absorbed && fit.ssd_read_bytes_per_token == 0.0);
        assert!(fit.tokens_per_s > miss.tokens_per_s, "{} vs {}", fit.tokens_per_s, miss.tokens_per_s);
    }

    #[test]
    fn vertical_decode_beats_horizontal_reload() {
        let v = simulate_serve(&ServeSimConfig { group: u64::MAX, ..base() });
        let h = simulate_serve(&ServeSimConfig { group: 1, ..base() });
        assert!(
            v.tokens_per_s > h.tokens_per_s,
            "vertical {} !> horizontal {}",
            v.tokens_per_s,
            h.tokens_per_s
        );
        // loads mirror the schedule closed form
        assert_eq!(serve_loads_per_token(&ServeSimConfig { group: u64::MAX, ..base() }), 8);
        assert_eq!(serve_loads_per_token(&ServeSimConfig { group: 1, ..base() }), 32);
        assert_eq!(serve_loads_per_token(&ServeSimConfig { group: 2, ..base() }), 16);
    }

    #[test]
    fn more_lanes_amortize_the_stream() {
        // batched decode: tokens/s grows with lanes under vertical order
        let b1 = simulate_serve(&ServeSimConfig { lanes: 1, ..base() });
        let b8 = simulate_serve(&ServeSimConfig { lanes: 8, ..base() });
        assert!(b8.tokens_per_s > 3.0 * b1.tokens_per_s, "{} vs {}", b8.tokens_per_s, b1.tokens_per_s);
    }
}
