//! Discrete-event pipeline simulator.
//!
//! The performance model gives closed-form steady-state times; this module
//! *executes* the schedules in virtual time instead — every parameter
//! prefetch, checkpoint swap, gradient offload, SSD transfer, and optimizer
//! step becomes an operation on a contended resource, so pipeline bubbles,
//! warm-up/drain, and cross-stage interference emerge instead of being
//! assumed away. This produces the "measured" series of Figures 10–12 on
//! the simulated testbed (DESIGN.md §Substitutions).
//!
//! The runtime's storage-tier knobs are mirrored by
//! [`schedules::simulate_store`]: `--ssds N` striping multiplies SSD
//! bandwidth (N independent throttles moving one object's shares in
//! parallel) and `--cpu-cache-mb` applies the fit-or-nothing DRAM-cache
//! absorption law shared with `traffic::Workload` and the runtime
//! `CachedStore`. [`schedules::simulate_store_prec`] adds the `--precision`
//! mirror: per-category storage byte multipliers
//! ([`crate::perfmodel::ByteMults`]) scale every modeled transfer and the
//! cache fit test, so half-precision storage both halves SSD time and fits
//! in caches its f32 twin overflows. [`schedules::simulate_planned`] mirrors
//! the multi-path `PlannedStore`: the SSD tier runs at the aggregate
//! bandwidth of the plan's concurrent DRAM/NVMe/remote paths
//! ([`schedules::planned_bandwidth`] — Σ path rates until a path saturates).
//! [`schedules::simulate_io_dev`] and [`dist::simulate_dist_dev`] replace
//! the flat SSD peak with an NVMe [`crate::memory::DeviceProfile`] curve —
//! QD ramp, request-size ramp, mix penalty, per-op latency floor, and the
//! `--io-batch` submission-window amortization — so small requests are
//! priced honestly; a flat profile is the exact identity, and these are the
//! objective the [`crate::autotune`] search minimizes.
//!
//! The forward-only serving engine has its own twin in [`serve`]:
//! schedule-ordered decode token steps streaming the shared base image (and
//! per-tenant adapters) under the same io-depth gate, striping, and
//! fit-or-nothing cache law, reporting steady-state tokens/sec against the
//! [`serve::serve_token_bound`] closed form (fig18).
//!
//! The data-parallel dimension lives in [`dist`]: W workers with their own
//! compute resources (incl. a first-class inter-GPU interconnect for the
//! ring-collective legs and a per-worker CPU-optimizer core) over one
//! shared `ssd-read`/`ssd-write` pair (or several — `--ssds`), a modeled
//! ring all-reduce feeding a rank-0 optimizer — or, with
//! [`dist::DistConfig::shard_optimizer`], a reduce-scatter feeding
//! ZeRO-style per-rank shard updates plus a parameter all-gather — and the
//! delayed-α split overlapping the next forward, mirroring the runtime's
//! `--workers W [--shard-optimizer]` engine.

pub mod dist;
pub mod engine;
pub mod schedules;
pub mod serve;

pub use dist::{simulate_dist, simulate_dist_dev, DistConfig};
pub use engine::{DiscreteSim, Resource, SimOp};
pub use schedules::{
    planned_bandwidth, simulate, simulate_io, simulate_io_dev, simulate_planned, simulate_store,
    simulate_store_prec, Schedule, SimResult,
};
pub use serve::{simulate_serve, serve_token_bound, ServeSimConfig, ServeSimResult};
