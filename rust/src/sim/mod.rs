//! Discrete-event pipeline simulator.
//!
//! The performance model gives closed-form steady-state times; this module
//! *executes* the schedules in virtual time instead — every parameter
//! prefetch, checkpoint swap, gradient offload, SSD transfer, and optimizer
//! step becomes an operation on a contended resource, so pipeline bubbles,
//! warm-up/drain, and cross-stage interference emerge instead of being
//! assumed away. This produces the "measured" series of Figures 10–12 on
//! the simulated testbed (DESIGN.md §Substitutions).
//!
//! The data-parallel dimension lives in [`dist`]: W workers with their own
//! compute resources over one shared `ssd-read`/`ssd-write` pair (or
//! several — `--ssds`), a modeled ring all-reduce, and a rank-0 optimizer,
//! mirroring the runtime's `--workers W` engine.

pub mod dist;
pub mod engine;
pub mod schedules;

pub use dist::simulate_dist;
pub use engine::{DiscreteSim, Resource, SimOp};
pub use schedules::{simulate, simulate_io, Schedule, SimResult};
