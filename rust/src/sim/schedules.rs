//! Schedule builders: lower each system's execution plan onto the
//! discrete-event engine.
//!
//! Resources: GPU compute, H2D copy, D2H copy, SSD read, SSD write, CPU
//! (optimizer). Each builder emits `iters` iterations chained by the
//! "layer updated before its next forward" dependency, and the reported
//! per-iteration time is the *steady-state* increment between the last two
//! iterations (warm-up excluded) — the same quantity the paper measures.

use crate::perfmodel::{ByteMults, HPlacement, StorageRatios, SystemParams};

use super::engine::{DiscreteSim, Resource};

pub const GPU: Resource = Resource(0);
pub const H2D: Resource = Resource(1);
pub const D2H: Resource = Resource(2);
pub const SSD_R: Resource = Resource(3);
pub const SSD_W: Resource = Resource(4);
pub const CPU: Resource = Resource(5);
pub const N_RESOURCES: usize = 6;

/// Which system to simulate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    /// GreedySnake: vertical scheduling with delay ratio α and placement x.
    GreedySnake { alpha: f64, x: StorageRatios },
    /// ZeRO-Infinity: horizontal scheduling, heuristic placement.
    ZeroInfinity,
    /// TeraIO: horizontal scheduling, lifetime-optimal placement.
    TeraIo,
    /// Ratel: single forward-backward pass at the max batch (extra ckpt).
    Ratel,
    /// Chunked-vertical (`chunked:G`): vertical sweeps over chunks of
    /// `group` micro-batches, parameters reloading once per chunk —
    /// the runtime's `ChunkedVerticalSchedule` on the event simulator.
    ChunkedVertical { group: u64, x: StorageRatios },
    /// Cache-sweep (`cachesweep:G`): `chunked:G` with the backward chunk
    /// order reversed (MLP-Offload's cache-friendly subgroup ordering).
    /// Per-iteration transfers are byte-identical to `chunked:G` — only the
    /// DRAM-tier reuse pattern differs — so the event model shares
    /// `build_chunked` and the same fit-or-nothing absorption law.
    CacheSweep { group: u64, x: StorageRatios },
}

impl Schedule {
    /// The runtime schedule name this system's traversal corresponds to —
    /// the same grammar `trainer::ScheduleKind` parses, so the analytic
    /// models and the real runtime name schedules consistently. (TeraIO
    /// traverses horizontally; Ratel's single pass has no runtime analog.)
    pub fn kind_name(&self) -> String {
        match self {
            Schedule::GreedySnake { .. } => "vertical".to_string(),
            Schedule::ZeroInfinity | Schedule::TeraIo => "horizontal".to_string(),
            Schedule::Ratel => "single-pass".to_string(),
            Schedule::ChunkedVertical { group, .. } => format!("chunked:{group}"),
            Schedule::CacheSweep { group, .. } => format!("cachesweep:{group}"),
        }
    }
}

/// Simulation output.
#[derive(Clone, Copy, Debug)]
pub struct SimResult {
    /// Steady-state seconds per iteration.
    pub t_iter: f64,
    /// Node tokens/s.
    pub tokens_per_s: f64,
    /// Model TFLOPs per GPU.
    pub tflops_per_gpu: f64,
    /// GPU busy fraction during the steady-state window.
    pub gpu_util: f64,
}

/// Simulate `m` micro-batches per iteration of `schedule` on `sp`, with the
/// sim's historical unbounded-prefetch assumption (loads may run arbitrarily
/// far ahead of compute).
pub fn simulate(sp: &SystemParams, m: u64, schedule: Schedule) -> SimResult {
    simulate_io(sp, m, schedule, usize::MAX)
}

/// Simulate with the runtime's storage-tier knobs mirrored on top of the
/// `--io-depth` lookahead:
///
/// * `ssds` — striping across N independent devices multiplies the
///   available SSD read/write bandwidth by N (the runtime's
///   [`StripedStore`](crate::memory::StripedStore) moves each object's
///   shares over N parallel throttles, which at layer-granular transfers
///   is exactly an N× aggregate-bandwidth path);
/// * `cache_bytes` — the CPU-DRAM cache tier: when the schedule's
///   SSD-resident working set fits
///   ([`Workload::cache_absorbs`](crate::traffic::Workload), the
///   fit-or-nothing LRU law shared with the runtime and the closed forms),
///   that traffic is served from DRAM — modeled by promoting the placement
///   ratios to `ALL_CPU`. Heuristic-placement baselines (ZeRO-Infinity /
///   TeraIO / Ratel) keep their own placement and ignore the cache knob.
///
/// `ssds = 1, cache_bytes = 0` is exactly [`simulate_io`].
pub fn simulate_store(
    sp: &SystemParams,
    m: u64,
    schedule: Schedule,
    io_depth: usize,
    ssds: usize,
    cache_bytes: u64,
) -> SimResult {
    let sp2 = scale_ssd_bandwidth(sp, ssds);
    let schedule2 = cache_adjusted(&sp2, m, schedule, cache_bytes);
    simulate_io(&sp2, m, schedule2, io_depth)
}

/// [`simulate_store`] with explicit per-category storage byte multipliers
/// (the `--precision` knob of the runtime mirrored onto the event sim): the
/// multipliers scale every parameter / checkpoint / gradient / optimizer
/// transfer AND the DRAM-cache working-set fit test, so a half-precision
/// store both moves fewer bytes and fits in a cache its f32 twin overflows.
/// `ByteMults::ONE` is the identity — exactly [`simulate_store`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_store_prec(
    sp: &SystemParams,
    m: u64,
    schedule: Schedule,
    io_depth: usize,
    ssds: usize,
    cache_bytes: u64,
    mults: ByteMults,
) -> SimResult {
    simulate_store(&sp.with_byte_mults(mults), m, schedule, io_depth, ssds, cache_bytes)
}

/// The multi-path aggregate-bandwidth law of the runtime's
/// [`PlannedStore`](crate::memory::PlannedStore): an object split into
/// per-path `shares` (bytes) moving concurrently over paths with the given
/// `rates` (bytes/s) completes when its *slowest* path finishes, so the
/// effective bandwidth is `Σ shares / max_i(share_i / rate_i)`. With shares
/// proportional to rates (the planner's weighting) this is exactly
/// `Σ rates` — throughput adds across paths until one saturates; a skewed
/// split degrades toward the bottleneck path's rate. Paths with a zero
/// share contribute nothing; an all-zero split is 0.
pub fn planned_bandwidth(shares: &[u64], rates: &[f64]) -> f64 {
    assert_eq!(shares.len(), rates.len(), "one rate per path");
    let total: u64 = shares.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut slowest = 0.0_f64;
    for (&s, &r) in shares.iter().zip(rates) {
        if s == 0 {
            continue;
        }
        assert!(r > 0.0, "a path with a non-zero share needs a positive rate");
        slowest = slowest.max(s as f64 / r);
    }
    total as f64 / slowest
}

/// Simulate with the SSD tier replaced by a multi-path planned store whose
/// aggregate read/write bandwidths are `read_bw` / `write_bw` — compute
/// them with [`planned_bandwidth`] from the plan's shares and per-path
/// rates. The DRAM-cache fit-or-nothing law still applies on top (the
/// planned store's DRAM path caches hot objects exactly like
/// `CachedStore`). With `read_bw`/`write_bw` equal to `sp`'s own SSD
/// bandwidths and `cache_bytes = 0` this is exactly [`simulate_io`].
pub fn simulate_planned(
    sp: &SystemParams,
    m: u64,
    schedule: Schedule,
    io_depth: usize,
    read_bw: f64,
    write_bw: f64,
    cache_bytes: u64,
) -> SimResult {
    assert!(read_bw > 0.0 && write_bw > 0.0, "planned aggregate bandwidths must be positive");
    let mut sp2 = *sp;
    sp2.node.machine.ssd_read_bw = read_bw;
    sp2.node.machine.ssd_write_bw = write_bw;
    let schedule2 = cache_adjusted(&sp2, m, schedule, cache_bytes);
    simulate_io(&sp2, m, schedule2, io_depth)
}

/// Simulate with the SSD tier priced by an NVMe
/// [`DeviceProfile`](crate::memory::DeviceProfile) curve instead of flat
/// peak bandwidth: the effective read/write rates come from
/// [`eff_bps`](crate::memory::DeviceProfile::eff_bps) at the run's steady
/// request sizes (`read_req`/`write_req` bytes — typically a layer's
/// checkpoint or parameter object, divided across the striped devices),
/// queue depth `io_depth` (the lanes keep that many transfers in flight),
/// and `batch_ops` submissions coalesced per `--io-batch` ring window
/// (1 = unbatched). Training traffic interleaves both directions, so the
/// mix penalty applies to each. This is how `simulate_io` prices small
/// requests *honestly*: sub-`sat_bytes` objects pay the size ramp and the
/// per-op latency floor unless batching amortizes it.
///
/// With a [`flat`](crate::memory::DeviceProfile::flat) profile at `sp`'s
/// own SSD bandwidths this is exactly [`simulate_io`] — the identity the
/// pin test holds bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn simulate_io_dev(
    sp: &SystemParams,
    m: u64,
    schedule: Schedule,
    io_depth: usize,
    profile: &crate::memory::DeviceProfile,
    read_req: u64,
    write_req: u64,
    batch_ops: u64,
) -> SimResult {
    let qd = io_depth.clamp(1, 1 << 20); // usize::MAX ⇒ past any knee
    let r = profile.eff_bps(false, read_req, qd, batch_ops) * profile.mix_frac();
    let w = profile.eff_bps(true, write_req, qd, batch_ops) * profile.mix_frac();
    let mut sp2 = *sp;
    sp2.node.machine.ssd_read_bw = r;
    sp2.node.machine.ssd_write_bw = w;
    simulate_io(&sp2, m, schedule, io_depth)
}

/// N striped devices = N× aggregate SSD bandwidth (each device keeps its
/// own full-rate throttle; shares move in parallel).
pub(crate) fn scale_ssd_bandwidth(sp: &SystemParams, ssds: usize) -> SystemParams {
    let k = ssds.max(1) as f64;
    let mut sp2 = *sp;
    sp2.node.machine.ssd_read_bw *= k;
    sp2.node.machine.ssd_write_bw *= k;
    sp2
}

/// Apply the DRAM-cache fit-or-nothing law to an explicit-placement
/// schedule: if the SSD-resident working set fits in `cache_bytes`, its
/// traffic is served from DRAM (ratios promote to `ALL_CPU`); otherwise
/// the cyclic sweep defeats the LRU and nothing is absorbed.
pub(crate) fn cache_adjusted(
    sp: &SystemParams,
    m: u64,
    schedule: Schedule,
    cache_bytes: u64,
) -> Schedule {
    if cache_bytes == 0 {
        return schedule;
    }
    let wl = crate::traffic::Workload {
        model: sp.model,
        micro_batch: sp.micro_batch,
        seq_len: sp.seq_len,
        m,
        shards: sp.node.n_gpus,
    };
    // the working-set fit test scales with the storage byte multipliers:
    // a mixed-precision store's SSD-resident state is smaller, so it can
    // fit in a cache the strict-f32 twin overflows (at `ByteMults::ONE`
    // this is term-for-term `Workload::ssd_working_set_bytes`)
    let bm = sp.byte_mults;
    let absorb = |x: StorageRatios| -> StorageRatios {
        let param = bm.param * (1.0 - x.param_cpu) * wl.ms_lp() as f64;
        let ckpt = bm.ckpt * (1.0 - x.ckpt_cpu) * (wl.m * wl.cs()) as f64;
        let opt = bm.opt * (1.0 - x.opt_cpu) * wl.opt_state_bytes() as f64;
        let ws = (param + ckpt + opt).ceil() as u64;
        if wl.cache_absorbs(ws, cache_bytes) {
            StorageRatios::ALL_CPU
        } else {
            x
        }
    };
    match schedule {
        Schedule::GreedySnake { alpha, x } => Schedule::GreedySnake { alpha, x: absorb(x) },
        Schedule::ChunkedVertical { group, x } => {
            Schedule::ChunkedVertical { group, x: absorb(x) }
        }
        Schedule::CacheSweep { group, x } => Schedule::CacheSweep { group, x: absorb(x) },
        other => other,
    }
}

/// Simulate with the runtime's `--io-depth` lookahead mirrored: a parameter
/// load may start at most `io_depth` visits ahead of compute (0 = fully
/// synchronous loads, `usize::MAX` = unbounded), so the simulator and the
/// real engine predict the same overlap.
pub fn simulate_io(sp: &SystemParams, m: u64, schedule: Schedule, io_depth: usize) -> SimResult {
    let iters = 3;
    let (makespan_all, gpu_busy) = build_and_run(sp, m, schedule, iters, io_depth);
    let (makespan_warm, _) = build_and_run(sp, m, schedule, iters - 1, io_depth);
    let t_iter = (makespan_all - makespan_warm).max(1e-9);

    let (eff_batch, flops) = match schedule {
        Schedule::Ratel => {
            let b = sp.single_pass_max_batch(true);
            (b, sp.model.iter_flops(b, sp.seq_len, 1))
        }
        _ => (
            m * sp.micro_batch,
            sp.model.iter_flops(sp.micro_batch, sp.seq_len, m),
        ),
    };
    let tokens = (sp.node.n_gpus * eff_batch * sp.seq_len) as f64;
    SimResult {
        t_iter,
        tokens_per_s: tokens / t_iter,
        tflops_per_gpu: flops / t_iter / 1e12,
        gpu_util: (gpu_busy / iters as f64 / t_iter).min(1.0),
    }
}

fn build_and_run(
    sp: &SystemParams,
    m: u64,
    schedule: Schedule,
    iters: u32,
    io_depth: usize,
) -> (f64, f64) {
    let mut sim = DiscreteSim::new(N_RESOURCES);
    let mut gate = IoGate::new(io_depth);
    match schedule {
        Schedule::GreedySnake { alpha, x } => {
            build_vertical(&mut sim, sp, m, alpha, x, iters, &mut gate)
        }
        Schedule::ZeroInfinity => {
            let pl = sp.zero_infinity_placement(m);
            build_horizontal(&mut sim, sp, m, pl, iters, &mut gate)
        }
        Schedule::TeraIo => {
            // lifetime-optimal placement: grid-searched via the perfmodel
            let pl = best_horizontal_placement(sp, m);
            build_horizontal(&mut sim, sp, m, pl, iters, &mut gate)
        }
        Schedule::Ratel => {
            let pl = sp.zero_infinity_placement(1);
            build_ratel(&mut sim, sp, pl, iters, &mut gate)
        }
        Schedule::ChunkedVertical { group, x } => {
            build_chunked(&mut sim, sp, m, group, x, iters, &mut gate)
        }
        // byte-identical transfers to chunked:G — only the DRAM-tier visit
        // order differs, which the event model's resources don't see
        Schedule::CacheSweep { group, x } => {
            build_chunked(&mut sim, sp, m, group, x, iters, &mut gate)
        }
    }
    let stats = sim.run();
    (stats.makespan, stats.busy[GPU.0])
}

/// The runtime IoPipeline's schedule-lookahead window, mirrored onto the
/// event simulator: parameter load *t* may not start before the compute of
/// load *t − K − 1* has finished. `K = 0` forces fully synchronous loads
/// (each waits for the previous load's compute), `usize::MAX` disables the
/// gate entirely — the unbounded prefetch the sim assumed before the
/// pipeline existed (no window *and* no barriers, preserving the historic
/// `simulate` behavior). For finite K, [`IoGate::barrier`] marks
/// pass/iteration boundaries: the runtime's `lookahead` only scans the
/// current pass's visit order and `flush` retires all lane I/O at the end of
/// every step, so no load may start before the previous pass's compute has
/// finished — without the barrier the sim would over-predict overlap at
/// exactly those boundaries.
pub(crate) struct IoGate {
    depth: usize,
    /// Last compute op of each load issued so far, in load order.
    computes: Vec<usize>,
    /// Last compute op before the most recent pass/step boundary.
    floor: Option<usize>,
}

impl IoGate {
    pub(crate) fn new(depth: usize) -> Self {
        IoGate { depth, computes: Vec::new(), floor: None }
    }

    /// Dependencies gating the load about to be issued (index = loads so
    /// far): the lookahead-window compute plus the current pass floor.
    pub(crate) fn gate(&self) -> Vec<usize> {
        if self.depth == usize::MAX {
            return Vec::new();
        }
        let mut deps = Vec::new();
        let t = self.computes.len();
        if let Some(i) = t.checked_sub(self.depth + 1) {
            deps.push(self.computes[i]);
        }
        // redundant (earlier than the window dep) for loads deep inside a
        // pass; binding only for a pass's first K loads
        deps.extend(self.floor);
        deps
    }

    /// Record the last compute op that consumed the load just issued.
    pub(crate) fn loaded(&mut self, compute_op: usize) {
        self.computes.push(compute_op);
    }

    /// Mark a pass/iteration boundary: later loads may not start before the
    /// compute issued so far (the runtime never looks ahead across a pass).
    pub(crate) fn barrier(&mut self) {
        if self.depth != usize::MAX {
            self.floor = self.computes.last().copied();
        }
    }
}

fn best_horizontal_placement(sp: &SystemParams, m: u64) -> HPlacement {
    let grad_cpu = sp.zero_infinity_placement(m).grad_cpu;
    let mut best: Option<(f64, HPlacement)> = None;
    for pc in [0.0, 0.25, 0.5, 0.75, 1.0] {
        for cc in [0.0, 0.25, 0.5, 0.75, 1.0] {
            for oc in [0.0, 0.25, 0.5] {
                let pl = HPlacement {
                    x: StorageRatios { ckpt_cpu: cc, param_cpu: pc, opt_cpu: oc },
                    grad_cpu,
                };
                if sp.cpu_bytes_horizontal(m, pl) > sp.dram_share() {
                    continue;
                }
                let est = sp.horizontal_iter(m, pl);
                if best.is_none_or(|(t, _)| est.t_iter < t) {
                    best = Some((est.t_iter, pl));
                }
            }
        }
    }
    best.map(|(_, pl)| pl)
        .unwrap_or(HPlacement { x: StorageRatios::ALL_SSD, grad_cpu })
}

/// Per-GPU SSD bandwidth shares.
fn rates(sp: &SystemParams) -> (f64, f64, f64) {
    let sh = sp.node.n_gpus as f64;
    (sp.node.ssd_read_bw() / sh, sp.node.ssd_write_bw() / sh, sp.node.pcie_bw_per_gpu())
}

// ---------------------------------------------------------------------------
// GreedySnake vertical pipeline (Figures 6–8)
// ---------------------------------------------------------------------------

fn build_vertical(
    sim: &mut DiscreteSim,
    sp: &SystemParams,
    m: u64,
    alpha: f64,
    x: StorageRatios,
    iters: u32,
    gate: &mut IoGate,
) {
    let n = sp.model.n_layers as usize;
    let mm = m as usize;
    let (r, w, pcie) = rates(sp);
    let (p, g, o, c) = (sp.p_lp(), sp.g_fp(), sp.o_bytes(), sp.c_bytes());

    // Per-layer ops of the previous iteration the next one must wait on.
    let mut prev_adam_b: Vec<Option<usize>> = vec![None; n]; // (1-α) share
    let mut prev_grad_off: Vec<Option<usize>> = vec![None; n];

    for _it in 0..iters {
        // ---------------- forward ----------------
        let mut fwd: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut d2h_ckpt: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut ckpt_ssd_w: Vec<Option<usize>> = vec![None; n];

        for i in 0..n {
            // Delayed α-share of the optimizer step overlapped with fwd
            // (Fig. 8): read opt states, CPU step, write back — must finish
            // before this layer's parameters upload.
            let mut param_deps: Vec<usize> = Vec::new();
            if alpha > 0.0 {
                if let Some(goff) = prev_grad_off[i] {
                    let ord = sim.op(SSD_R, alpha * (1.0 - x.opt_cpu) * o / r, &[]);
                    let ad = sim.op(CPU, alpha * sp.t_adam_layer(), &[ord, goff]);
                    let _owr = sim.op(
                        SSD_W,
                        alpha * ((1.0 - x.opt_cpu) * o + (1.0 - x.param_cpu) * p) / w,
                        &[ad],
                    );
                    param_deps.push(ad);
                }
            }
            if let Some(ab) = prev_adam_b[i] {
                param_deps.push(ab); // (1-α) share updated during prev bwd
            }
            // Parameter prefetch: SSD→CPU then CPU→GPU (micro-batch chunks
            // merged into one transfer of equal total size), gated by the
            // lookahead window.
            param_deps.extend(gate.gate());
            let prd = sim.op(SSD_R, (1.0 - x.param_cpu) * p / r, &param_deps);
            let ph2d = sim.op(H2D, p / pcie, &[prd]);

            for j in 0..mm {
                let mut deps = vec![ph2d];
                if i > 0 {
                    // input checkpoint: produced by layer i-1, staged through
                    // CPU except the boundary micro-batch (alternating order).
                    let produced = d2h_ckpt[i - 1][j];
                    if j == 0 {
                        deps.push(fwd[i - 1][j]); // stays in GPU memory
                    } else {
                        let h = sim.op(H2D, c / pcie, &[produced]);
                        deps.push(h);
                    }
                }
                let f = sim.op(GPU, sp.t_fwd_mb(), &deps);
                fwd[i].push(f);
                let dc = sim.op(D2H, c / pcie, &[f]);
                d2h_ckpt[i].push(dc);
            }
            gate.loaded(*fwd[i].last().expect("m >= 1"));
            // SSD share of this layer's checkpoints, written layer-granular
            // in the next stage (overlaps layer i+1's forward).
            if x.ckpt_cpu < 1.0 {
                let wop =
                    sim.op(SSD_W, (1.0 - x.ckpt_cpu) * m as f64 * c / w, &d2h_ckpt[i]);
                ckpt_ssd_w[i] = Some(wop);
            }
        }

        // ---------------- backward + (1-α) optimizer (Fig. 7) -------------
        gate.barrier(); // runtime lookahead never crosses the pass boundary
        let mut bwd: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut d2h_gout: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut new_adam_b: Vec<Option<usize>> = vec![None; n];
        let mut new_grad_off: Vec<Option<usize>> = vec![None; n];

        for i in (0..n).rev() {
            // recompute needs the layer parameters again
            let pdeps: Vec<usize> = gate.gate();
            let prd = sim.op(SSD_R, (1.0 - x.param_cpu) * p / r, &pdeps);
            let ph2d = sim.op(H2D, p / pcie, &[prd]);
            // input checkpoints: SSD share arrives one stage early
            let mut ckpt_deps: Vec<usize> = Vec::new();
            if let Some(wop) = ckpt_ssd_w[i] {
                let rop = sim.op(SSD_R, (1.0 - x.ckpt_cpu) * m as f64 * c / r, &[wop]);
                ckpt_deps.push(rop);
            }
            for j in 0..mm {
                let mut deps = vec![ph2d];
                // input activation checkpoint of (i, j)
                let mut h2d_deps = ckpt_deps.clone();
                if i > 0 {
                    h2d_deps.push(d2h_ckpt[i - 1][j]);
                }
                let hck = sim.op(H2D, c / pcie, &h2d_deps);
                deps.push(hck);
                // upstream gradient from layer i+1 via CPU (boundary
                // micro-batch forwarded directly in GPU memory)
                if i + 1 < n {
                    if j == 0 {
                        deps.push(bwd[i + 1][j]);
                    } else {
                        let hg = sim.op(H2D, c / pcie, &[d2h_gout[i + 1][j]]);
                        deps.push(hg);
                    }
                }
                let b = sim.op(GPU, sp.t_bwd_mb(), &deps);
                bwd[i].push(b);
                let dg = sim.op(D2H, c / pcie, &[b]);
                d2h_gout[i].push(dg);
            }
            gate.loaded(*bwd[i].last().expect("m >= 1"));
            // fully-accumulated parameter gradients leave the GPU once
            let goff = sim.op(D2H, g / pcie, &bwd[i]);
            new_grad_off[i] = Some(goff);
            // (1-α) optimizer share: opt-state read ∥ grads, then CPU Adam,
            // then write-back of updated states + SSD-resident params.
            let ord = sim.op(SSD_R, (1.0 - alpha) * (1.0 - x.opt_cpu) * o / r, &[]);
            let ad = sim.op(CPU, (1.0 - alpha) * sp.t_adam_layer(), &[ord, goff]);
            let _owr = sim.op(
                SSD_W,
                (1.0 - alpha) * ((1.0 - x.opt_cpu) * o + (1.0 - x.param_cpu) * p) / w,
                &[ad],
            );
            new_adam_b[i] = Some(ad);
        }
        prev_adam_b = new_adam_b;
        prev_grad_off = new_grad_off;
        gate.barrier(); // the runtime flushes all lane I/O at step end
    }
}

// ---------------------------------------------------------------------------
// Horizontal pipeline (ZeRO-Infinity / TeraIO)
// ---------------------------------------------------------------------------

fn build_horizontal(
    sim: &mut DiscreteSim,
    sp: &SystemParams,
    m: u64,
    pl: HPlacement,
    iters: u32,
    gate: &mut IoGate,
) {
    let n = sp.model.n_layers as usize;
    let mm = m as usize;
    let x = pl.x;
    let (r, w, pcie) = rates(sp);
    let (p, g, o, c) = (sp.p_lp(), sp.g_fp(), sp.o_bytes(), sp.c_bytes());

    let mut prev_iter_adam: Vec<Option<usize>> = vec![None; n];

    for _it in 0..iters {
        // -------- forward: all layers of mb 0, then mb 1, … --------------
        let mut d2h_ckpt: Vec<Vec<usize>> = vec![vec![0; n]; mm];
        let mut last_fwd: Option<usize> = None;
        for j in 0..mm {
            for i in 0..n {
                let mut pdeps: Vec<usize> = Vec::new();
                if let Some(ad) = prev_iter_adam[i] {
                    pdeps.push(ad);
                }
                pdeps.extend(gate.gate());
                let prd = sim.op(SSD_R, (1.0 - x.param_cpu) * p / r, &pdeps);
                let ph2d = sim.op(H2D, p / pcie, &[prd]);
                let mut deps = vec![ph2d];
                if let Some(lf) = last_fwd {
                    deps.push(lf); // sequential within a micro-batch chain
                }
                let f = sim.op(GPU, sp.t_fwd_mb(), &deps);
                last_fwd = Some(f);
                gate.loaded(f);
                let dc = sim.op(D2H, c / pcie, &[f]);
                if x.ckpt_cpu < 1.0 {
                    sim.op(SSD_W, (1.0 - x.ckpt_cpu) * c / w, &[dc]);
                }
                d2h_ckpt[j][i] = dc;
            }
        }

        // -------- backward + optimizer ------------------------------------
        gate.barrier(); // runtime lookahead never crosses the pass boundary
        let mut grad_ready: Vec<usize> = vec![0; n]; // last accumulation op
        let mut last_bwd: Option<usize> = last_fwd;
        for j in 0..mm {
            for i in (0..n).rev() {
                let pdeps: Vec<usize> = gate.gate();
                let prd = sim.op(SSD_R, (1.0 - x.param_cpu) * p / r, &pdeps);
                let ph2d = sim.op(H2D, p / pcie, &[prd]);
                // checkpoint back in (SSD share first)
                let mut cdeps = vec![d2h_ckpt[j][i]];
                if x.ckpt_cpu < 1.0 {
                    let cr = sim.op(SSD_R, (1.0 - x.ckpt_cpu) * c / r, &[d2h_ckpt[j][i]]);
                    cdeps.push(cr);
                }
                let hck = sim.op(H2D, c / pcie, &cdeps);
                let mut deps = vec![ph2d, hck];
                if let Some(lb) = last_bwd {
                    deps.push(lb);
                }
                // gradient-accumulation buffer round trip (j > 0 fetches).
                // PCIe legs move fp16 (g/2); the CPU buffer is fp32.
                if j > 0 {
                    let mut gdeps = vec![grad_ready[i]];
                    if pl.grad_cpu < 1.0 {
                        let gr =
                            sim.op(SSD_R, (1.0 - pl.grad_cpu) * g / r, &[grad_ready[i]]);
                        gdeps.push(gr);
                    }
                    let gh = sim.op(H2D, g / 2.0 / pcie, &gdeps);
                    deps.push(gh);
                }
                let b = sim.op(GPU, sp.t_bwd_mb(), &deps);
                last_bwd = Some(b);
                gate.loaded(b);
                let goff = sim.op(D2H, g / 2.0 / pcie, &[b]);
                grad_ready[i] = if pl.grad_cpu < 1.0 {
                    sim.op(SSD_W, (1.0 - pl.grad_cpu) * g / w, &[goff])
                } else {
                    goff
                };
                // optimizer step for this layer after the LAST micro-batch
                if j == mm - 1 {
                    let ord = sim.op(SSD_R, (1.0 - x.opt_cpu) * o / r, &[]);
                    let ad = sim.op(CPU, sp.t_adam_layer(), &[ord, grad_ready[i]]);
                    sim.op(
                        SSD_W,
                        ((1.0 - x.opt_cpu) * o + (1.0 - x.param_cpu) * p) / w,
                        &[ad],
                    );
                    prev_iter_adam[i] = Some(ad);
                }
            }
        }
        gate.barrier(); // the runtime flushes all lane I/O at step end
    }
}

// ---------------------------------------------------------------------------
// Chunked-vertical pipeline (vertical sweeps over chunks of G micro-batches)
// ---------------------------------------------------------------------------

/// Mirrors the runtime's `ChunkedVerticalSchedule`: all chunks run their
/// forward sweep, then all chunks run their backward sweep; parameters
/// reload once per (layer, chunk); the per-layer gradient buffer
/// round-trips between chunks (fp16 PCIe legs, like the horizontal
/// builder); the optimizer runs per layer after the last chunk. Checkpoint
/// transfers are modeled chunk-granular. No delayed-α split (the runtime
/// supports it for chunked schedules, but the simulator models the α = 0
/// configuration the equivalence experiments use).
#[allow(clippy::too_many_arguments)]
fn build_chunked(
    sim: &mut DiscreteSim,
    sp: &SystemParams,
    m: u64,
    group: u64,
    x: StorageRatios,
    iters: u32,
    gate: &mut IoGate,
) {
    let n = sp.model.n_layers as usize;
    let g_mb = group.max(1);
    let k = m.div_ceil(g_mb) as usize;
    let chunk_size = |ci: usize| (m - ci as u64 * g_mb).min(g_mb) as f64;
    let (r, w, pcie) = rates(sp);
    let (p, g, o, c) = (sp.p_lp(), sp.g_fp(), sp.o_bytes(), sp.c_bytes());

    let mut prev_iter_adam: Vec<Option<usize>> = vec![None; n];

    for _it in 0..iters {
        // -------- forward: chunk-major, vertical within each chunk --------
        let mut d2h_ckpt: Vec<Vec<usize>> = vec![vec![0; k]; n];
        let mut ckpt_ssd_w: Vec<Vec<Option<usize>>> = vec![vec![None; k]; n];
        let mut last_gpu: Option<usize> = None; // single-device program order
        for ci in 0..k {
            let gi = chunk_size(ci);
            for i in 0..n {
                let mut pdeps: Vec<usize> = Vec::new();
                if let Some(ad) = prev_iter_adam[i] {
                    pdeps.push(ad);
                }
                pdeps.extend(gate.gate());
                let prd = sim.op(SSD_R, (1.0 - x.param_cpu) * p / r, &pdeps);
                let ph2d = sim.op(H2D, p / pcie, &[prd]);
                let mut deps = vec![ph2d];
                if i > 0 {
                    // the chunk's input activations staged through CPU
                    let h = sim.op(H2D, gi * c / pcie, &[d2h_ckpt[i - 1][ci]]);
                    deps.push(h);
                }
                if let Some(lg) = last_gpu {
                    deps.push(lg);
                }
                let f = sim.op(GPU, gi * sp.t_fwd_mb(), &deps);
                last_gpu = Some(f);
                gate.loaded(f);
                let dc = sim.op(D2H, gi * c / pcie, &[f]);
                d2h_ckpt[i][ci] = dc;
                if x.ckpt_cpu < 1.0 {
                    ckpt_ssd_w[i][ci] =
                        Some(sim.op(SSD_W, (1.0 - x.ckpt_cpu) * gi * c / w, &[dc]));
                }
            }
        }

        // -------- backward + gradient round trips + optimizer -------------
        gate.barrier(); // runtime lookahead never crosses the pass boundary
        let mut grad_ready: Vec<Option<usize>> = vec![None; n];
        for ci in 0..k {
            let gi = chunk_size(ci);
            for i in (0..n).rev() {
                let pdeps: Vec<usize> = gate.gate();
                let prd = sim.op(SSD_R, (1.0 - x.param_cpu) * p / r, &pdeps);
                let ph2d = sim.op(H2D, p / pcie, &[prd]);
                // input checkpoints back in (SSD share first)
                let mut cdeps = vec![d2h_ckpt[i][ci]];
                if let Some(wop) = ckpt_ssd_w[i][ci] {
                    cdeps.push(sim.op(SSD_R, (1.0 - x.ckpt_cpu) * gi * c / r, &[wop]));
                }
                let hck = sim.op(H2D, gi * c / pcie, &cdeps);
                let mut deps = vec![ph2d, hck];
                if let Some(lg) = last_gpu {
                    deps.push(lg);
                }
                // accumulation buffer fetch for every chunk after the first
                if ci > 0 {
                    let gh = sim.op(
                        H2D,
                        g / 2.0 / pcie,
                        &[grad_ready[i].expect("prior chunk offloaded")],
                    );
                    deps.push(gh);
                }
                let b = sim.op(GPU, gi * sp.t_bwd_mb(), &deps);
                last_gpu = Some(b);
                gate.loaded(b);
                let goff = sim.op(D2H, g / 2.0 / pcie, &[b]);
                grad_ready[i] = Some(goff);
                // optimizer step for this layer after the LAST chunk
                if ci == k - 1 {
                    let ord = sim.op(SSD_R, (1.0 - x.opt_cpu) * o / r, &[]);
                    let ad = sim.op(CPU, sp.t_adam_layer(), &[ord, goff]);
                    sim.op(
                        SSD_W,
                        ((1.0 - x.opt_cpu) * o + (1.0 - x.param_cpu) * p) / w,
                        &[ad],
                    );
                    prev_iter_adam[i] = Some(ad);
                }
            }
        }
        gate.barrier(); // the runtime flushes all lane I/O at step end
    }
}

// ---------------------------------------------------------------------------
// Ratel single-pass pipeline
// ---------------------------------------------------------------------------

fn build_ratel(
    sim: &mut DiscreteSim,
    sp: &SystemParams,
    pl: HPlacement,
    iters: u32,
    gate: &mut IoGate,
) {
    let n = sp.model.n_layers as usize;
    let x = pl.x;
    let (r, w, pcie) = rates(sp);
    let (p, g, o) = (sp.p_lp(), sp.g_fp(), sp.o_bytes());
    let batch = sp.single_pass_max_batch(true);
    let scale = batch as f64 / sp.micro_batch as f64;
    // double checkpoint frequency (attention/FFN boundary)
    let c = 2.0 * scale * sp.c_bytes();
    let t_fwd = scale * sp.t_fwd_mb();
    let t_bwd = scale * sp.t_bwd_mb();

    let mut prev_iter_adam: Vec<Option<usize>> = vec![None; n];
    for _it in 0..iters {
        let mut d2h_ckpt: Vec<usize> = vec![0; n];
        let mut last: Option<usize> = None;
        for i in 0..n {
            let mut pdeps: Vec<usize> = Vec::new();
            if let Some(ad) = prev_iter_adam[i] {
                pdeps.push(ad);
            }
            pdeps.extend(gate.gate());
            let prd = sim.op(SSD_R, (1.0 - x.param_cpu) * p / r, &pdeps);
            let ph2d = sim.op(H2D, p / pcie, &[prd]);
            let mut deps = vec![ph2d];
            if let Some(l) = last {
                deps.push(l);
            }
            let f = sim.op(GPU, t_fwd, &deps);
            last = Some(f);
            gate.loaded(f);
            let dc = sim.op(D2H, c / pcie, &[f]);
            if x.ckpt_cpu < 1.0 {
                sim.op(SSD_W, (1.0 - x.ckpt_cpu) * c / w, &[dc]);
            }
            d2h_ckpt[i] = dc;
        }
        gate.barrier(); // lookahead never crosses the pass boundary
        for i in (0..n).rev() {
            let pdeps: Vec<usize> = gate.gate();
            let prd = sim.op(SSD_R, (1.0 - x.param_cpu) * p / r, &pdeps);
            let ph2d = sim.op(H2D, p / pcie, &[prd]);
            let mut cdeps = vec![d2h_ckpt[i]];
            if x.ckpt_cpu < 1.0 {
                let cr = sim.op(SSD_R, (1.0 - x.ckpt_cpu) * c / r, &[d2h_ckpt[i]]);
                cdeps.push(cr);
            }
            let hck = sim.op(H2D, c / pcie, &cdeps);
            let mut deps = vec![ph2d, hck];
            if let Some(l) = last {
                deps.push(l);
            }
            let b = sim.op(GPU, t_bwd, &deps);
            last = Some(b);
            gate.loaded(b);
            let goff = sim.op(D2H, g / pcie, &[b]);
            // Ratel overlaps the optimizer with the backward pass.
            let ord = sim.op(SSD_R, (1.0 - x.opt_cpu) * o / r, &[]);
            let ad = sim.op(CPU, sp.t_adam_layer(), &[ord, goff]);
            sim.op(SSD_W, ((1.0 - x.opt_cpu) * o + (1.0 - x.param_cpu) * p) / w, &[ad]);
            prev_iter_adam[i] = Some(ad);
        }
        gate.barrier(); // the runtime flushes all lane I/O at step end
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MACHINE2_A100;
    use crate::modelcfg::{GPT_65B, SEQ_LEN};
    use crate::perfmodel::SystemParams;

    fn sp() -> SystemParams {
        // A shortened GPT-65B (8 layers) keeps op counts small while
        // preserving all per-layer ratios.
        let mut model = GPT_65B;
        model.n_layers = 8;
        SystemParams::new(MACHINE2_A100.with_gpus(1), model, 2, SEQ_LEN)
    }

    fn gs(alpha: f64) -> Schedule {
        Schedule::GreedySnake {
            alpha,
            x: StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 },
        }
    }

    /// Full-size GPT-65B on one A100 — the Fig. 10 headline point. The
    /// 8-layer miniature used in the cheap tests hides the CPU-memory
    /// pressure (checkpoints/grads spilling to SSD) that creates the real
    /// gap, so this test uses all 80 layers.
    #[test]
    fn greedysnake_beats_zero_infinity_saturated() {
        let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
        let x = crate::lp::solve_config(&sp, 32, 0.3).expect("feasible").ratios;
        let v = simulate(&sp, 32, Schedule::GreedySnake { alpha: 0.3, x });
        let h = simulate(&sp, 32, Schedule::ZeroInfinity);
        assert!(
            v.tokens_per_s > 1.5 * h.tokens_per_s,
            "v={} h={}",
            v.tokens_per_s,
            h.tokens_per_s
        );
    }

    #[test]
    fn sim_tracks_perfmodel_within_2x() {
        // The event-driven makespan should be in the same ballpark as the
        // closed form (bubbles make it slower, never 2× slower at steady
        // state for uniform layers).
        let sp = sp();
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        let sim_r = simulate(&sp, 16, gs(0.3));
        let pm = sp.vertical_iter(16, 0.3, x);
        let ratio = sim_r.t_iter / pm.t_iter;
        assert!(ratio > 0.5 && ratio < 2.0, "sim {} vs pm {}", sim_r.t_iter, pm.t_iter);
    }

    #[test]
    fn throughput_monotone_then_saturating() {
        let sp = sp();
        let t2 = simulate(&sp, 2, gs(0.3)).tokens_per_s;
        let t16 = simulate(&sp, 16, gs(0.3)).tokens_per_s;
        let t48 = simulate(&sp, 48, gs(0.3)).tokens_per_s;
        let t96 = simulate(&sp, 96, gs(0.3)).tokens_per_s;
        assert!(t16 > t2);
        assert!(t48 >= t16 * 0.99);
        assert!((t96 - t48) / t48 < 0.12, "{t48} -> {t96} should be near saturation");
    }

    #[test]
    fn gpu_util_high_when_saturated() {
        let sp = sp();
        let r = simulate(&sp, 64, gs(0.3));
        assert!(r.gpu_util > 0.8, "{}", r.gpu_util);
    }

    #[test]
    fn teraio_between_zero_and_greedysnake() {
        // Full model: placement differences only matter under memory
        // pressure (§6.2 — TeraIO's win over ZeRO-Infinity is "local").
        let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
        let z = simulate(&sp, 16, Schedule::ZeroInfinity).tokens_per_s;
        let t = simulate(&sp, 16, Schedule::TeraIo).tokens_per_s;
        let x = crate::lp::solve_config(&sp, 16, 0.3).expect("feasible").ratios;
        let v = simulate(&sp, 16, Schedule::GreedySnake { alpha: 0.3, x }).tokens_per_s;
        assert!(t >= z * 0.98, "teraio {t} vs zero {z}");
        assert!(v > t, "greedysnake {v} vs teraio {t}");
    }

    #[test]
    fn chunked_between_horizontal_and_vertical() {
        // Full model: the parameter-reload gap only dominates when layers
        // are large relative to checkpoints (§3.4).
        let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        let v = simulate(&sp, 16, Schedule::GreedySnake { alpha: 0.0, x }).tokens_per_s;
        let ch = simulate(&sp, 16, Schedule::ChunkedVertical { group: 4, x }).tokens_per_s;
        let h = simulate(&sp, 16, Schedule::ZeroInfinity).tokens_per_s;
        assert!(ch > 0.0);
        // more chunks = more parameter reloads = no faster than vertical...
        assert!(ch <= v * 1.02, "chunked {ch} vs vertical {v}");
        // ...but far fewer reloads than per-micro-batch horizontal
        assert!(ch >= h, "chunked {ch} vs horizontal {h}");
    }

    /// The io-depth gate mirrors the runtime lookahead: tightening the
    /// window can only add dependencies, so iteration time is monotonically
    /// non-increasing in K, and fully synchronous loads (K = 0) are strictly
    /// slower than the unbounded prefetch when loads carry real SSD time.
    #[test]
    fn io_depth_gating_orders_iteration_times() {
        let sp = sp();
        let sync = simulate_io(&sp, 12, gs(0.3), 0).t_iter;
        let k1 = simulate_io(&sp, 12, gs(0.3), 1).t_iter;
        let k4 = simulate_io(&sp, 12, gs(0.3), 4).t_iter;
        let unbounded = simulate_io(&sp, 12, gs(0.3), usize::MAX).t_iter;
        assert!(sync >= k1 * 0.999, "sync {sync} vs K=1 {k1}");
        assert!(k1 >= k4 * 0.999, "K=1 {k1} vs K=4 {k4}");
        assert!(k4 >= unbounded * 0.999, "K=4 {k4} vs unbounded {unbounded}");
        assert!(sync > unbounded * 1.01, "gating must cost something: {sync} vs {unbounded}");
    }

    /// `simulate` (no depth argument) is exactly the unbounded window.
    #[test]
    fn default_simulate_is_unbounded_lookahead() {
        let sp = sp();
        let a = simulate(&sp, 8, gs(0.2));
        let b = simulate_io(&sp, 8, gs(0.2), usize::MAX);
        assert_eq!(a.t_iter, b.t_iter);
        let z = simulate(&sp, 8, Schedule::ZeroInfinity);
        let z2 = simulate_io(&sp, 8, Schedule::ZeroInfinity, usize::MAX);
        assert_eq!(z.t_iter, z2.t_iter);
    }

    /// The non-gated striping acceptance property: with SSD-resident state,
    /// striping over 2 devices strictly reduces the simulated iteration
    /// time, and `ssds = 1, cache = 0` reproduces `simulate_io` exactly.
    #[test]
    fn striped_ssd_bandwidth_speeds_ssd_bound_schedule() {
        let sp = sp();
        let sched = Schedule::GreedySnake { alpha: 0.0, x: StorageRatios::ALL_SSD };
        let one = simulate_store(&sp, 8, sched, usize::MAX, 1, 0);
        let two = simulate_store(&sp, 8, sched, usize::MAX, 2, 0);
        assert!(
            two.t_iter < 0.99 * one.t_iter,
            "2 striped devices {} must beat 1 {}",
            two.t_iter,
            one.t_iter
        );
        let plain = simulate_io(&sp, 8, sched, usize::MAX);
        assert_eq!(one.t_iter, plain.t_iter, "ssds=1 cache=0 must be simulate_io");
    }

    /// The non-gated cache acceptance property: absorption is
    /// fit-or-nothing — a cache below the working set changes nothing, a
    /// fitting one serves the SSD-resident state from DRAM (exactly the
    /// ALL_CPU placement) and strictly beats the SSD-bound run.
    #[test]
    fn cache_absorption_follows_fit_or_nothing_law() {
        let sp = sp();
        let sched = Schedule::GreedySnake { alpha: 0.0, x: StorageRatios::ALL_SSD };
        let none = simulate_store(&sp, 8, sched, usize::MAX, 1, 0);
        let tiny = simulate_store(&sp, 8, sched, usize::MAX, 1, 1 << 20);
        assert_eq!(tiny.t_iter, none.t_iter, "a 1 MiB cache absorbs nothing here");
        let huge = simulate_store(&sp, 8, sched, usize::MAX, 1, u64::MAX);
        assert!(
            huge.t_iter < 0.99 * none.t_iter,
            "a fitting cache {} must beat the SSD-bound run {}",
            huge.t_iter,
            none.t_iter
        );
        let all_cpu = simulate_io(
            &sp,
            8,
            Schedule::GreedySnake { alpha: 0.0, x: StorageRatios::ALL_CPU },
            usize::MAX,
        );
        assert_eq!(
            huge.t_iter, all_cpu.t_iter,
            "full absorption IS the ALL_CPU placement"
        );
    }

    #[test]
    fn schedule_kind_names_are_runtime_grammar() {
        let x = StorageRatios::ALL_SSD;
        assert_eq!(Schedule::GreedySnake { alpha: 0.3, x }.kind_name(), "vertical");
        assert_eq!(Schedule::ZeroInfinity.kind_name(), "horizontal");
        assert_eq!(Schedule::TeraIo.kind_name(), "horizontal");
        assert_eq!(Schedule::Ratel.kind_name(), "single-pass");
        assert_eq!(Schedule::ChunkedVertical { group: 4, x }.kind_name(), "chunked:4");
        assert_eq!(Schedule::CacheSweep { group: 4, x }.kind_name(), "cachesweep:4");
    }

    /// Cachesweep's per-iteration transfers are byte-identical to chunked:G
    /// (only the DRAM visit order differs), so the event model must agree
    /// exactly.
    #[test]
    fn cachesweep_event_model_matches_chunked() {
        let sp = sp();
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        let ch = simulate(&sp, 16, Schedule::ChunkedVertical { group: 4, x });
        let cs = simulate(&sp, 16, Schedule::CacheSweep { group: 4, x });
        assert_eq!(cs.t_iter, ch.t_iter);
        assert_eq!(cs.tokens_per_s, ch.tokens_per_s);
    }

    /// The multi-path aggregate law: proportional shares add rates exactly;
    /// a skewed split is bottlenecked by its slowest path; degenerate
    /// splits are well-defined.
    #[test]
    fn planned_bandwidth_follows_aggregate_law() {
        // shares proportional to rates: 30 + 10 + 10 MB/s = 50 MB/s
        let bw = planned_bandwidth(&[30, 10, 10], &[30e6, 10e6, 10e6]);
        assert!((bw - 50e6).abs() < 1.0, "{bw}");
        // everything on the slow path: the aggregate IS that path
        let bw = planned_bandwidth(&[0, 100, 0], &[30e6, 10e6, 10e6]);
        assert!((bw - 10e6).abs() < 1.0, "{bw}");
        // skewed split: 50/50 over a 30/10 pair finishes with the slow
        // path — 100 bytes in max(50/30e6, 50/10e6) s = 20 MB/s
        let bw = planned_bandwidth(&[50, 50], &[30e6, 10e6]);
        assert!((bw - 20e6).abs() < 1.0, "{bw}");
        assert_eq!(planned_bandwidth(&[0, 0], &[30e6, 10e6]), 0.0);
    }

    /// `simulate_planned` pinned to its two endpoints: at the machine's own
    /// SSD bandwidths it is exactly `simulate_io`, and the planned
    /// multi-path aggregate strictly beats the best single path on an
    /// SSD-bound schedule.
    #[test]
    fn simulate_planned_aggregates_paths() {
        let sp = sp();
        let sched = Schedule::GreedySnake { alpha: 0.0, x: StorageRatios::ALL_SSD };
        let (r, w) = (sp.node.machine.ssd_read_bw, sp.node.machine.ssd_write_bw);
        let same = simulate_planned(&sp, 8, sched, usize::MAX, r, w, 0);
        let plain = simulate_io(&sp, 8, sched, usize::MAX);
        assert_eq!(same.t_iter, plain.t_iter, "identity pin");
        // two extra equal-rate paths triple the aggregate
        let shares = [1_u64, 1, 1];
        let agg_r = planned_bandwidth(&shares, &[r, r, r]);
        let agg_w = planned_bandwidth(&shares, &[w, w, w]);
        let multi = simulate_planned(&sp, 8, sched, usize::MAX, agg_r, agg_w, 0);
        assert!(
            multi.t_iter < 0.99 * plain.t_iter,
            "multi-path {} must beat single-path {}",
            multi.t_iter,
            plain.t_iter
        );
    }

    /// Device-curve sim pins: a flat profile at the machine's own rates is
    /// bit-identical to plain `simulate_io` at every io-depth; a profiled
    /// device makes small requests strictly slower on an SSD-bound
    /// schedule, and coalescing submissions (`batch_ops > 1`) claws the
    /// loss back monotonically.
    #[test]
    fn simulate_io_dev_flat_identity_and_curve_effects() {
        use crate::memory::DeviceProfile;
        let sp = sp();
        let sched = Schedule::GreedySnake { alpha: 0.0, x: StorageRatios::ALL_SSD };
        let (r, w) = (sp.node.machine.ssd_read_bw, sp.node.machine.ssd_write_bw);
        let flat = DeviceProfile::flat(r, w);
        for depth in [1usize, 2, usize::MAX] {
            let dev = simulate_io_dev(&sp, 8, sched, depth, &flat, 4096, 4096, 1);
            let plain = simulate_io(&sp, 8, sched, depth);
            assert_eq!(dev.t_iter, plain.t_iter, "flat identity at depth {depth}");
        }
        // A realistic curve: small requests pay the size ramp + latency
        // floor and the run slows down...
        let curvy = DeviceProfile {
            qd_knee: 8,
            sat_bytes: 1 << 20,
            mix_penalty: 0.1,
            op_latency_s: 100e-6,
            ..flat
        };
        let small = simulate_io_dev(&sp, 8, sched, 2, &curvy, 64 << 10, 64 << 10, 1);
        let plain = simulate_io(&sp, 8, sched, 2);
        assert!(
            small.t_iter > plain.t_iter,
            "profiled small requests {} must be slower than flat {}",
            small.t_iter,
            plain.t_iter
        );
        // ...and batching monotonically recovers toward (never past) flat.
        let b8 = simulate_io_dev(&sp, 8, sched, 2, &curvy, 64 << 10, 64 << 10, 8);
        assert!(b8.t_iter <= small.t_iter, "batched must not be slower than unbatched");
        assert!(b8.t_iter >= plain.t_iter * 0.999, "curve never beats flat peak");
    }

    #[test]
    fn ratel_runs_and_underperforms() {
        let sp = sp();
        let rr = simulate(&sp, 1, Schedule::Ratel);
        let v = simulate(&sp, 48, gs(0.3));
        assert!(rr.tokens_per_s > 0.0);
        assert!(rr.tokens_per_s < v.tokens_per_s);
    }

    /// The precision knob on the event sim: `ByteMults::ONE` is the exact
    /// identity, and a mixed-precision store (half-width params/ckpts,
    /// requantized grads) strictly beats the strict-f32 store (2× paper
    /// wire widths) on an SSD-bound schedule.
    #[test]
    fn precision_byte_mults_scale_simulated_ssd_time() {
        use crate::memory::codec::Precision;
        let sp = sp();
        let sched = Schedule::GreedySnake { alpha: 0.0, x: StorageRatios::ALL_SSD };
        let base = simulate_store(&sp, 8, sched, usize::MAX, 1, 0);
        let one = simulate_store_prec(&sp, 8, sched, usize::MAX, 1, 0, ByteMults::ONE);
        assert_eq!(one.t_iter, base.t_iter, "ByteMults::ONE is the identity");
        let strict = simulate_store_prec(
            &sp,
            8,
            sched,
            usize::MAX,
            1,
            0,
            ByteMults::for_precision(Precision::F32),
        );
        let mixed = simulate_store_prec(
            &sp,
            8,
            sched,
            usize::MAX,
            1,
            0,
            ByteMults::for_precision(Precision::MixedF16),
        );
        assert!(
            mixed.t_iter < strict.t_iter,
            "mixed {} must beat strict f32 {}",
            mixed.t_iter,
            strict.t_iter
        );
    }

    /// The cache fit test scales with the byte multipliers: a cache sized
    /// to the mixed-precision working set absorbs under `mixed:f16` but
    /// not under strict f32, whose stored bytes are 2× larger.
    #[test]
    fn cache_fit_respects_byte_mults() {
        use crate::memory::codec::Precision;
        let sp = sp();
        let sched = Schedule::GreedySnake { alpha: 0.0, x: StorageRatios::ALL_SSD };
        let wl = crate::traffic::Workload {
            model: sp.model,
            micro_batch: sp.micro_batch,
            seq_len: sp.seq_len,
            m: 8,
            shards: sp.node.n_gpus,
        };
        // mixed mults are 1/1/1 on the param/ckpt/opt terms, so the
        // mixed-precision working set IS the paper-width closed form
        let ws_mixed = wl.ssd_working_set_bytes(0.0, 0.0, 0.0);
        let strict = ByteMults::for_precision(Precision::F32);
        let mixed = ByteMults::for_precision(Precision::MixedF16);
        let m_un = simulate_store_prec(&sp, 8, sched, usize::MAX, 1, 0, mixed);
        let m_c = simulate_store_prec(&sp, 8, sched, usize::MAX, 1, ws_mixed, mixed);
        assert!(
            m_c.t_iter < 0.99 * m_un.t_iter,
            "mixed working set fits: {} vs {}",
            m_c.t_iter,
            m_un.t_iter
        );
        let s_un = simulate_store_prec(&sp, 8, sched, usize::MAX, 1, 0, strict);
        let s_c = simulate_store_prec(&sp, 8, sched, usize::MAX, 1, ws_mixed, strict);
        assert_eq!(s_c.t_iter, s_un.t_iter, "the f32 working set is 2x and overflows");
    }

    #[test]
    fn delayed_alpha_helps_in_transition_region() {
        let sp = sp();
        let a0 = simulate(&sp, 12, gs(0.0)).tokens_per_s;
        let mut best = a0;
        for a in [0.1, 0.2, 0.3, 0.4, 0.5] {
            best = best.max(simulate(&sp, 12, gs(a)).tokens_per_s);
        }
        assert!(best > a0 * 1.03, "best {best} vs a0 {a0}");
    }
}
