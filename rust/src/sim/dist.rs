//! Multi-worker (data-parallel) discrete-event simulation — the
//! `--workers W` mirror of [`crate::coordinator::dist::DataParallelEngine`].
//!
//! W workers each get their own compute resources (GPU, H2D, D2H lanes, an
//! inter-GPU interconnect leg, and a CPU-optimizer core) but share `ssds`
//! SSD read/write resource pairs (workers are assigned round-robin), so
//! contention on the shared tier — the effect MLP-Offload (arXiv
//! 2509.02480) shows dominates multi-worker offloaded scaling — is modeled
//! rather than assumed away. The iteration structure matches the runtime
//! engine:
//!
//! * each worker runs its contiguous micro-batch share through the
//!   schedule's traversal (the visit order restricted to its share, grouped
//!   into per-layer spans), parameters reloading per span exactly like the
//!   runtime's one-layer cache, gated by the per-worker `--io-depth`
//!   lookahead window;
//! * fully-accumulated per-layer gradients leave each worker once
//!   (D2H, fp32), then a ring collective joins all workers — each leg rides
//!   its worker's *interconnect* resource
//!   ([`NodeSpec::link_bw_per_gpu`](crate::machine::NodeSpec) — NVLink, or
//!   PCIe P2P where there is none), a first-class resource distinct from
//!   the host PCIe lanes the parameter/checkpoint traffic uses;
//! * the optimizer mirrors the runtime's two modes. **Rank-0** (default):
//!   the full update runs once per layer on rank 0's CPU + SSD pair, and
//!   every worker's next-iteration load of that layer waits on it.
//!   **Sharded** ([`DistConfig::shard_optimizer`]): the ring leg is a
//!   reduce-scatter ((W−1)/W·g per rank), each rank updates its 1/W shard
//!   on its OWN CPU core with ~1/W of the optimizer-state SSD round trip on
//!   its own assigned SSD pair, and the updated parameter shards
//!   all-gather ((W−1)/W·p per rank) before the next iteration's parameter
//!   prefetch — the ZeRO-style partitioning that makes CPU-optimizer time
//!   shrink with W;
//! * the delayed-α split is modeled like the single-worker vertical builder
//!   (Fig. 8): the α share of each layer's update dispatches at the start
//!   of the next iteration, overlapping its forward, and that layer's
//!   parameter loads wait on it (per rank in sharded mode).

use crate::coordinator::dist::{partition, ring_leg_frac};
use crate::coordinator::schedule::{
    ChunkedVerticalSchedule, HorizontalSchedule, Schedule as Traversal, VerticalSchedule,
};
use crate::perfmodel::{ByteMults, StorageRatios, SystemParams};

use super::engine::{DiscreteSim, Resource};
use super::schedules::{IoGate, Schedule, SimResult};

/// Multi-worker simulation knobs (the `--workers/--ssds/--io-depth/
/// --shard-optimizer` CLI surface).
#[derive(Clone, Copy, Debug)]
pub struct DistConfig {
    /// Data-parallel worker count W (≥ 1).
    pub workers: usize,
    /// Modeled SSDs shared by the workers (round-robin assignment).
    pub ssds: usize,
    /// Per-worker lookahead window (`usize::MAX` = unbounded).
    pub io_depth: usize,
    /// ZeRO-style sharded optimizer states: reduce-scatter + per-rank
    /// update + parameter all-gather instead of the rank-0 optimizer.
    pub shard_optimizer: bool,
    /// Persistence-sharded master parameters (the runtime `--param-persist`
    /// mirror): every update round-trips the rank's parameter shard through
    /// the store — a full p read before and p write after the Adam op
    /// (÷W per rank in sharded mode), regardless of the placement ratios'
    /// `param_cpu` (master parameters live on the store, not the host).
    pub param_persist: bool,
    /// Modeled CPU-DRAM cache tier, bytes (the runtime `--cpu-cache-mb`
    /// mirror): when the schedule's SSD-resident working set fits, its
    /// traffic is served from DRAM — the same fit-or-nothing law
    /// `sim::schedules::simulate_store` applies. 0 = off.
    pub cache_bytes: u64,
    /// Per-category storage byte multipliers (the `--precision` mirror —
    /// see [`ByteMults::for_precision`]). Applied to `sp` at simulation
    /// entry, replacing whatever multipliers `sp` already carries;
    /// [`ByteMults::ONE`] (the default) models the paper's wire widths.
    pub byte_mults: ByteMults,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            workers: 1,
            ssds: 1,
            io_depth: usize::MAX,
            shard_optimizer: false,
            param_persist: false,
            cache_bytes: 0,
            byte_mults: ByteMults::ONE,
        }
    }
}

/// Simulate `m` GLOBAL micro-batches per iteration, split contiguously
/// across `cfg.workers` data-parallel workers sharing `cfg.ssds` SSDs.
/// `workers == 1, ssds == 1` is the degenerate single-worker pipeline.
pub fn simulate_dist(sp: &SystemParams, m: u64, schedule: Schedule, cfg: DistConfig) -> SimResult {
    let sp = &sp.with_byte_mults(cfg.byte_mults);
    let iters = 3;
    let (mk_all, busy_all) = build_and_run(sp, m, schedule, iters, cfg);
    let (mk_warm, _) = build_and_run(sp, m, schedule, iters - 1, cfg);
    let t_iter = (mk_all - mk_warm).max(1e-9);
    let w = cfg.workers.max(1) as f64;
    let tokens = (m * sp.micro_batch * sp.seq_len) as f64;
    let flops = sp.model.iter_flops(sp.micro_batch, sp.seq_len, m);
    SimResult {
        t_iter,
        tokens_per_s: tokens / t_iter,
        tflops_per_gpu: flops / w / t_iter / 1e12,
        gpu_util: (busy_all / w / iters as f64 / t_iter).min(1.0),
    }
}

/// [`simulate_dist`] with the shared SSD tier priced by an NVMe
/// [`DeviceProfile`](crate::memory::DeviceProfile) curve — the dist twin of
/// [`simulate_io_dev`](super::schedules::simulate_io_dev), and the
/// objective the [`crate::autotune`] search minimizes. Effective per-device
/// read/write rates come from
/// [`eff_bps`](crate::memory::DeviceProfile::eff_bps) at the steady request
/// sizes (`read_req`/`write_req` bytes) and the per-worker queue depth,
/// times the mix penalty (training traffic interleaves both directions);
/// each of the `cfg.ssds` modeled devices then runs at that rate. A flat
/// profile at `sp`'s own SSD bandwidths is exactly [`simulate_dist`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_dist_dev(
    sp: &SystemParams,
    m: u64,
    schedule: Schedule,
    cfg: DistConfig,
    profile: &crate::memory::DeviceProfile,
    read_req: u64,
    write_req: u64,
    batch_ops: u64,
) -> SimResult {
    let qd = cfg.io_depth.clamp(1, 1 << 20);
    let r = profile.eff_bps(false, read_req, qd, batch_ops) * profile.mix_frac();
    let w = profile.eff_bps(true, write_req, qd, batch_ops) * profile.mix_frac();
    let mut sp2 = *sp;
    sp2.node.machine.ssd_read_bw = r;
    sp2.node.machine.ssd_write_bw = w;
    simulate_dist(&sp2, m, schedule, cfg)
}

/// Storage ratios the schedule implies (the dist builder needs only x; the
/// horizontal baselines use their heuristic placement).
fn ratios_of(sp: &SystemParams, m: u64, schedule: Schedule) -> StorageRatios {
    match schedule {
        Schedule::GreedySnake { x, .. } | Schedule::ChunkedVertical { x, .. } => x,
        Schedule::ZeroInfinity | Schedule::TeraIo | Schedule::Ratel => {
            sp.zero_infinity_placement(m).x
        }
    }
}

/// The delay ratio the dist builder models: GreedySnake's α; 0 for every
/// other system (the chunked builder, like its single-worker counterpart,
/// models the α = 0 configuration the equivalence experiments use).
fn alpha_of(schedule: Schedule) -> f64 {
    match schedule {
        Schedule::GreedySnake { alpha, .. } => alpha,
        _ => 0.0,
    }
}

/// The runtime traversal policy this system's schedule corresponds to
/// (Ratel has no runtime analog; its single pass is closest to horizontal).
fn traversal_of(schedule: Schedule) -> Box<dyn Traversal> {
    match schedule {
        Schedule::GreedySnake { .. } => Box::new(VerticalSchedule),
        Schedule::ZeroInfinity | Schedule::TeraIo | Schedule::Ratel => {
            Box::new(HorizontalSchedule)
        }
        Schedule::ChunkedVertical { group, .. } => {
            Box::new(ChunkedVerticalSchedule::new(group as usize))
        }
    }
}

/// Consecutive same-layer visits of a restricted order: `(layer, count)` —
/// exactly the granularity at which the runtime's one-layer parameter cache
/// reloads.
type Spans = Vec<(usize, u64)>;

/// One forward span's checkpoint ops: (D2H op, optional SSD-write op).
type CkptOps = (usize, Option<usize>);

/// Group a (restricted) visit order into per-layer spans.
fn spans(order: &[(usize, usize)]) -> Spans {
    let mut out: Spans = Vec::new();
    for &(l, _) in order {
        match out.last_mut() {
            Some((pl, count)) if *pl == l => *count += 1,
            _ => out.push((l, 1)),
        }
    }
    out
}

fn build_and_run(
    sp: &SystemParams,
    m: u64,
    schedule: Schedule,
    iters: u32,
    cfg: DistConfig,
) -> (f64, f64) {
    // the DRAM cache tier (fit-or-nothing absorption) adjusts the
    // explicit-placement schedules' ratios exactly as the single-worker
    // store mirror does
    let schedule = super::schedules::cache_adjusted(sp, m, schedule, cfg.cache_bytes);
    let w_n = cfg.workers.max(1);
    let s_n = cfg.ssds.max(1);
    let io_depth = cfg.io_depth;
    let shard = cfg.shard_optimizer && w_n > 1;
    // layout: per worker [gpu, h2d, d2h, link, cpu], then per ssd
    // [read, write]. The rank-0 optimizer is worker 0's CPU core; sharded
    // mode uses every worker's core.
    let n_res = 5 * w_n + 2 * s_n;
    let gpu = |w: usize| Resource(5 * w);
    let h2d = |w: usize| Resource(5 * w + 1);
    let d2h = |w: usize| Resource(5 * w + 2);
    let link = |w: usize| Resource(5 * w + 3);
    let cpu = |w: usize| Resource(5 * w + 4);
    let ssd_r = |w: usize| Resource(5 * w_n + 2 * (w % s_n));
    let ssd_w = |w: usize| Resource(5 * w_n + 2 * (w % s_n) + 1);
    let mut sim = DiscreteSim::new(n_res);

    let x = ratios_of(sp, m, schedule);
    let alpha = alpha_of(schedule);
    let policy = traversal_of(schedule);
    let n = sp.model.n_layers as usize;
    // each modeled SSD provides the node's full bandwidth (sharing between
    // workers is explicit through the resource, not a rate divisor)
    let (r, wbw, pcie, lbw) = (
        sp.node.ssd_read_bw(),
        sp.node.ssd_write_bw(),
        sp.node.pcie_bw_per_gpu(),
        sp.node.link_bw_per_gpu(),
    );
    let (p, g, o, c) = (sp.p_lp(), sp.g_fp(), sp.o_bytes(), sp.c_bytes());
    let w_f = w_n as f64; // optimizer shard divisor (sharded mode)
    // --param-persist byte deltas at every update site: the master-parameter
    // shard is READ from the store before the Adam op (p_rd) and the updated
    // shard written back after (p_wr replaces the placement-scaled write) —
    // the store is the parameter home, so `x.param_cpu` no longer discounts
    // the update-side parameter bytes.
    let p_rd = if cfg.param_persist { p } else { 0.0 };
    let p_wr = if cfg.param_persist { p } else { (1.0 - x.param_cpu) * p };

    let parts = partition(m as usize, w_n);
    let active: Vec<usize> = (0..w_n).filter(|&w| !parts[w].is_empty()).collect();
    let fwd_full = policy.forward_order(n, m as usize);
    let bwd_full = policy.backward_order(n, m as usize);
    let worker_spans: Vec<(Spans, Spans)> = parts
        .iter()
        .map(|range| {
            let f: Vec<(usize, usize)> =
                fwd_full.iter().copied().filter(|&(_, j)| range.contains(&j)).collect();
            let b: Vec<(usize, usize)> =
                bwd_full.iter().copied().filter(|&(_, j)| range.contains(&j)).collect();
            (spans(&f), spans(&b))
        })
        .collect();

    // ring leg fractions — the same (W−1)/W arithmetic the byte helpers in
    // coordinator::dist use, so modeled traffic and closed forms agree. The
    // unsharded all-reduce runs among ACTIVE workers; the sharded
    // reduce-scatter / all-gather span the whole group (every rank owns an
    // optimizer shard).
    let allreduce_frac = 2.0 * ring_leg_frac(active.len());
    let shard_frac = ring_leg_frac(w_n);
    let mut gates: Vec<IoGate> = (0..w_n).map(|_| IoGate::new(io_depth)).collect();
    // per-layer ops of the previous iteration the next one depends on:
    // the eager update(s) a layer's parameter load must wait for (rank-0
    // Adam op, or the all-gather legs in sharded mode) ...
    let mut prev_update: Vec<Vec<usize>> = vec![Vec::new(); n];
    // ... and the ring ops whose reduced gradients the delayed-α share
    // consumes (empty until the layer's first backward).
    let mut prev_grad_ready: Vec<Vec<usize>> = vec![Vec::new(); n];
    // each worker's GPU is one serial stream across the whole run
    let mut last_gpu: Vec<Option<usize>> = vec![None; w_n];

    for _it in 0..iters {
        // -------- delayed α share (overlaps this forward, Fig. 8) ---------
        // Dispatched once per layer at iteration start — exactly the
        // runtime's dispatch_delayed — and every worker's forward load of
        // the layer waits on it through `delayed_ops`.
        let mut delayed_ops: Vec<Vec<usize>> = vec![Vec::new(); n];
        if alpha > 0.0 {
            for l in 0..n {
                if prev_grad_ready[l].is_empty() {
                    continue; // first iteration: nothing accumulated yet
                }
                if shard {
                    for rk in 0..w_n {
                        let ord = sim.op(
                            ssd_r(rk),
                            alpha * ((1.0 - x.opt_cpu) * o + p_rd) / w_f / r,
                            &[],
                        );
                        let mut adeps = prev_grad_ready[l].clone();
                        adeps.push(ord);
                        let ad = sim.op(cpu(rk), alpha * sp.t_adam_layer() / w_f, &adeps);
                        sim.op(
                            ssd_w(rk),
                            alpha * ((1.0 - x.opt_cpu) * o + p_wr) / w_f / wbw,
                            &[ad],
                        );
                        delayed_ops[l].push(ad);
                    }
                } else {
                    let ord =
                        sim.op(ssd_r(0), alpha * ((1.0 - x.opt_cpu) * o + p_rd) / r, &[]);
                    let mut adeps = prev_grad_ready[l].clone();
                    adeps.push(ord);
                    let ad = sim.op(cpu(0), alpha * sp.t_adam_layer(), &adeps);
                    sim.op(
                        ssd_w(0),
                        alpha * ((1.0 - x.opt_cpu) * o + p_wr) / wbw,
                        &[ad],
                    );
                    delayed_ops[l].push(ad);
                }
            }
        }

        // fwd_ckpt[w][l] = the layer's checkpoint ops per span, in span order
        let mut fwd_ckpt: Vec<Vec<Vec<CkptOps>>> = vec![vec![Vec::new(); n]; w_n];
        // -------- forward, per worker --------------------------------------
        for &w in &active {
            for &(l, span) in &worker_spans[w].0 {
                let mut pdeps: Vec<usize> = gates[w].gate();
                // cross-worker "update layer l before its forward": the
                // previous iteration's eager update / all-gather, plus this
                // iteration's delayed α share
                pdeps.extend(&prev_update[l]);
                pdeps.extend(&delayed_ops[l]);
                let prd = sim.op(ssd_r(w), (1.0 - x.param_cpu) * p / r, &pdeps);
                let ph2d = sim.op(h2d(w), p / pcie, &[prd]);
                let mut deps = vec![ph2d];
                if let Some(lg) = last_gpu[w] {
                    deps.push(lg);
                }
                let f = sim.op(gpu(w), span as f64 * sp.t_fwd_mb(), &deps);
                last_gpu[w] = Some(f);
                gates[w].loaded(f);
                let dc = sim.op(d2h(w), span as f64 * c / pcie, &[f]);
                let wop = if x.ckpt_cpu < 1.0 {
                    Some(sim.op(ssd_w(w), (1.0 - x.ckpt_cpu) * span as f64 * c / wbw, &[dc]))
                } else {
                    None
                };
                fwd_ckpt[w][l].push((dc, wop));
            }
            gates[w].barrier(); // lookahead never crosses the pass boundary
        }

        // -------- backward, per worker -------------------------------------
        let mut grad_off: Vec<Vec<Option<usize>>> = vec![vec![None; n]; w_n];
        for &w in &active {
            let mut used: Vec<usize> = vec![0; n];
            let mut remaining: Vec<u64> = vec![parts[w].len() as u64; n];
            for &(l, span) in &worker_spans[w].1 {
                let pdeps: Vec<usize> = gates[w].gate();
                let prd = sim.op(ssd_r(w), (1.0 - x.param_cpu) * p / r, &pdeps);
                let ph2d = sim.op(h2d(w), p / pcie, &[prd]);
                // the span's input checkpoints back in (SSD share first);
                // backward spans of a layer arrive in the same order its
                // forward spans were produced for every traversal policy
                let (dc, wop) = fwd_ckpt[w][l][used[l]];
                used[l] += 1;
                let mut cdeps = vec![dc];
                if let Some(wo) = wop {
                    cdeps.push(sim.op(
                        ssd_r(w),
                        (1.0 - x.ckpt_cpu) * span as f64 * c / r,
                        &[wo],
                    ));
                }
                let hck = sim.op(h2d(w), span as f64 * c / pcie, &cdeps);
                let mut deps = vec![ph2d, hck];
                if let Some(lg) = last_gpu[w] {
                    deps.push(lg);
                }
                let b = sim.op(gpu(w), span as f64 * sp.t_bwd_mb(), &deps);
                last_gpu[w] = Some(b);
                gates[w].loaded(b);
                remaining[l] -= span;
                if remaining[l] == 0 {
                    // fully-accumulated gradients leave this worker once
                    grad_off[w][l] = Some(sim.op(d2h(w), g / pcie, &[b]));
                }
            }
            gates[w].barrier(); // the runtime flushes all lane I/O at step end
        }

        // -------- ring collective + (1-α) optimizer, per layer -------------
        // Descending layer order, like the runtime's submission order.
        for l in (0..n).rev() {
            let offs: Vec<usize> = active
                .iter()
                .map(|&w| grad_off[w][l].expect("worker offloaded layer gradient"))
                .collect();
            if shard {
                // reduce-scatter: every rank's leg depends on all workers'
                // offloads and moves (W−1)/W·g over ITS interconnect
                let rs_legs: Vec<usize> = (0..w_n)
                    .map(|rk| sim.op(link(rk), shard_frac * g / lbw, &offs))
                    .collect();
                // per-rank eager update: 1/W of the CPU Adam work and of the
                // optimizer-state round trip, on the rank's own SSD pair
                let adam_ops: Vec<usize> = (0..w_n)
                    .map(|rk| {
                        let ord = sim.op(
                            ssd_r(rk),
                            (1.0 - alpha) * ((1.0 - x.opt_cpu) * o + p_rd) / w_f / r,
                            &[],
                        );
                        let ad = sim.op(
                            cpu(rk),
                            (1.0 - alpha) * sp.t_adam_layer() / w_f,
                            &[rs_legs[rk], ord],
                        );
                        sim.op(
                            ssd_w(rk),
                            (1.0 - alpha) * ((1.0 - x.opt_cpu) * o + p_wr) / w_f / wbw,
                            &[ad],
                        );
                        ad
                    })
                    .collect();
                // all-gather of the updated parameter shards — the next
                // iteration's parameter prefetch of this layer waits on it
                let ag_legs: Vec<usize> = (0..w_n)
                    .map(|rk| sim.op(link(rk), shard_frac * p / lbw, &adam_ops))
                    .collect();
                prev_update[l] = ag_legs;
                prev_grad_ready[l] = rs_legs;
            } else {
                // all-reduce among the active workers: each leg moves
                // 2·(W−1)/W·g over its worker's interconnect
                let legs: Vec<usize> = active
                    .iter()
                    .map(|&w| sim.op(link(w), allreduce_frac * g / lbw, &offs))
                    .collect();
                let ord =
                    sim.op(ssd_r(0), (1.0 - alpha) * ((1.0 - x.opt_cpu) * o + p_rd) / r, &[]);
                let mut adeps = legs.clone();
                adeps.push(ord);
                let ad = sim.op(cpu(0), (1.0 - alpha) * sp.t_adam_layer(), &adeps);
                sim.op(
                    ssd_w(0),
                    (1.0 - alpha) * ((1.0 - x.opt_cpu) * o + p_wr) / wbw,
                    &[ad],
                );
                prev_update[l] = vec![ad];
                prev_grad_ready[l] = legs;
            }
        }
    }

    let stats = sim.run();
    let gpu_busy: f64 = (0..w_n).map(|w| stats.busy[gpu(w).0]).sum();
    (stats.makespan, gpu_busy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MACHINE2_A100;
    use crate::modelcfg::{GPT_65B, SEQ_LEN};

    fn sp() -> SystemParams {
        let mut model = GPT_65B;
        model.n_layers = 8;
        SystemParams::new(MACHINE2_A100.with_gpus(1), model, 2, SEQ_LEN)
    }

    fn gs(x: StorageRatios) -> Schedule {
        Schedule::GreedySnake { alpha: 0.0, x }
    }

    fn cfg(workers: usize, ssds: usize) -> DistConfig {
        DistConfig { workers, ssds, ..DistConfig::default() }
    }

    /// Device-curve pin: a flat profile at the machine's own rates leaves
    /// `simulate_dist_dev` bit-identical to `simulate_dist`, and a curved
    /// profile strictly slows small-request SSD-bound traffic.
    #[test]
    fn simulate_dist_dev_flat_identity() {
        use crate::memory::DeviceProfile;
        let sp = sp();
        let x = StorageRatios::ALL_SSD;
        let (r, w) = (sp.node.machine.ssd_read_bw, sp.node.machine.ssd_write_bw);
        let flat = DeviceProfile::flat(r, w);
        let dev = simulate_dist_dev(&sp, 16, gs(x), cfg(2, 1), &flat, 4096, 4096, 1);
        let plain = simulate_dist(&sp, 16, gs(x), cfg(2, 1));
        assert_eq!(dev.t_iter, plain.t_iter, "flat identity");
        let curvy =
            DeviceProfile { qd_knee: 8, sat_bytes: 1 << 20, op_latency_s: 100e-6, ..flat };
        let mut c = cfg(2, 1);
        c.io_depth = 2;
        let slow = simulate_dist_dev(&sp, 16, gs(x), c, &curvy, 64 << 10, 64 << 10, 1);
        let base = simulate_dist(&sp, 16, gs(x), c);
        assert!(
            slow.t_iter > base.t_iter,
            "curved small-request profile {} must be slower than flat {}",
            slow.t_iter,
            base.t_iter
        );
    }

    /// The satellite contention property: two workers hammering ONE SSD are
    /// strictly slower than the same two workers over two modeled SSDs.
    #[test]
    fn shared_ssd_contention_slows_two_workers() {
        let sp = sp();
        let x = StorageRatios::ALL_SSD;
        let one = simulate_dist(&sp, 16, gs(x), cfg(2, 1)).t_iter;
        let two = simulate_dist(&sp, 16, gs(x), cfg(2, 2)).t_iter;
        assert!(
            one > two * 1.02,
            "one shared SSD {one} must cost more than two: {two}"
        );
    }

    /// The fig12-scaling property: with a quarter of the parameters on the
    /// one shared SSD, adding workers speeds the iteration up — each worker
    /// computes a smaller micro-batch share — but stays strictly
    /// sub-linear, because every worker re-reads the FULL parameter set
    /// from the shared device (total SSD traffic grows with W while
    /// compute shrinks).
    #[test]
    fn scaling_is_monotone_but_sublinear() {
        let sp = sp();
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.75, opt_cpu: 1.0 };
        let t1 = simulate_dist(&sp, 16, gs(x), cfg(1, 1)).t_iter;
        let t2 = simulate_dist(&sp, 16, gs(x), cfg(2, 1)).t_iter;
        let t4 = simulate_dist(&sp, 16, gs(x), cfg(4, 1)).t_iter;
        assert!(t2 < t1, "W=2 {t2} must beat W=1 {t1}");
        assert!(t4 < t2, "W=4 {t4} must beat W=2 {t2}");
        assert!(
            t1 / t4 < 3.99,
            "W=4 speedup {} must be sub-linear under the shared SSD",
            t1 / t4
        );
    }

    /// The degenerate W=1 build is the same pipeline shape as the
    /// single-worker vertical builder — coarser (span-granular GPU ops, no
    /// boundary-micro-batch residency), but the same work totals, so the
    /// two agree within a small factor under a compute-dominated placement.
    #[test]
    fn w1_tracks_single_worker_sim() {
        let sp = sp();
        let x = StorageRatios::ALL_CPU;
        for alpha in [0.0, 0.3] {
            let sched = Schedule::GreedySnake { alpha, x };
            let dist = simulate_dist(&sp, 12, sched, cfg(1, 1)).t_iter;
            let single = super::super::schedules::simulate(&sp, 12, sched).t_iter;
            let ratio = dist / single;
            assert!(
                ratio > 0.5 && ratio < 2.0,
                "α={alpha}: dist {dist} vs single {single}"
            );
        }
    }

    /// Tightening the per-worker lookahead window can only slow things down
    /// (same monotonicity the single-worker gate obeys).
    #[test]
    fn io_depth_gating_monotone_for_workers() {
        let sp = sp();
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        let sync = simulate_dist(&sp, 12, gs(x), DistConfig { io_depth: 0, ..cfg(2, 1) }).t_iter;
        let unbounded = simulate_dist(&sp, 12, gs(x), cfg(2, 1)).t_iter;
        assert!(sync >= unbounded * 0.999, "sync {sync} vs unbounded {unbounded}");
    }

    /// All traversal policies run through the dist builder (spans differ,
    /// plumbing must not), in both optimizer modes.
    #[test]
    fn all_schedules_build_and_run() {
        let sp = sp();
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        for s in [
            gs(x),
            Schedule::ZeroInfinity,
            Schedule::ChunkedVertical { group: 2, x },
        ] {
            for w in [1usize, 2, 3, 4] {
                for shard in [false, true] {
                    let c = DistConfig { shard_optimizer: shard, ..cfg(w, 1) };
                    let r = simulate_dist(&sp, 8, s, c);
                    assert!(
                        r.t_iter.is_finite() && r.t_iter > 0.0,
                        "{s:?} W={w} shard={shard}"
                    );
                    assert!(
                        r.gpu_util > 0.0 && r.gpu_util <= 1.0,
                        "{s:?} W={w} shard={shard}"
                    );
                }
            }
        }
        // more workers than micro-batches: extras idle, still well-formed
        let r = simulate_dist(&sp, 2, gs(x), cfg(4, 2));
        assert!(r.t_iter.is_finite() && r.t_iter > 0.0);
        let r = simulate_dist(&sp, 2, gs(x), DistConfig { shard_optimizer: true, ..cfg(4, 2) });
        assert!(r.t_iter.is_finite() && r.t_iter > 0.0);
    }

    /// The tentpole property: in the CPU-optimizer-bound regime (optimizer
    /// states on the shared SSD, everything else CPU-resident), sharding
    /// the optimizer strictly beats the rank-0 update at W = 4 — the
    /// per-rank 1/W CPU + SSD round trips are the whole point of the
    /// ZeRO-style split — and the sharded path never helps at W = 1.
    #[test]
    fn sharded_optimizer_beats_rank0_when_optimizer_bound() {
        let sp = sp();
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 1.0, opt_cpu: 0.0 };
        let sched = gs(x);
        let rank0 = simulate_dist(&sp, 16, sched, cfg(4, 4)).t_iter;
        let sharded =
            simulate_dist(&sp, 16, sched, DistConfig { shard_optimizer: true, ..cfg(4, 4) })
                .t_iter;
        assert!(
            sharded < rank0 * 0.98,
            "sharded {sharded} must beat rank-0 {rank0} when optimizer-bound"
        );
        // degenerate W=1: both modes are the same pipeline
        let a = simulate_dist(&sp, 16, sched, cfg(1, 1)).t_iter;
        let b =
            simulate_dist(&sp, 16, sched, DistConfig { shard_optimizer: true, ..cfg(1, 1) })
                .t_iter;
        assert!((a - b).abs() <= 1e-9 * a.max(1.0), "W=1: {a} vs {b}");
    }

    /// Delayed-α modeling in the dist sim. In the transition regime the
    /// single-worker sim's `delayed_alpha_helps_in_transition_region` pins
    /// down (same placement, same M), the W = 1 dist build must show the
    /// same effect: some α > 0 beats α = 0, because the delayed share
    /// overlaps the next forward instead of blocking it. At W = 2 (where a
    /// saturated shared SSD can make the makespan α-invariant) every α must
    /// still build and run in both optimizer modes.
    #[test]
    fn delayed_alpha_modeled_in_dist() {
        let sp = sp();
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        let a0 = simulate_dist(&sp, 12, Schedule::GreedySnake { alpha: 0.0, x }, cfg(1, 1));
        let mut best = a0.tokens_per_s;
        for alpha in [0.1, 0.2, 0.3, 0.4, 0.5] {
            let r = simulate_dist(&sp, 12, Schedule::GreedySnake { alpha, x }, cfg(1, 1));
            assert!(r.t_iter.is_finite() && r.t_iter > 0.0, "α={alpha}");
            best = best.max(r.tokens_per_s);
        }
        // the sim is deterministic, so any consistent gain is real modeling
        // (the fine-grained single-worker builder shows ~3% here; the
        // span-granular dist builder is coarser, so only a conservative
        // floor is pinned)
        assert!(
            best > a0.tokens_per_s * 1.005,
            "some α must help at W=1: best {best} vs α=0 {}",
            a0.tokens_per_s
        );
        for shard in [false, true] {
            let c = DistConfig { shard_optimizer: shard, ..cfg(2, 1) };
            for alpha in [0.0, 0.25, 0.5] {
                let r = simulate_dist(&sp, 12, Schedule::GreedySnake { alpha, x }, c);
                assert!(
                    r.t_iter.is_finite() && r.t_iter > 0.0,
                    "α={alpha} shard={shard}"
                );
            }
        }
    }

    /// The `--param-persist` mirror: with everything host-resident except
    /// the round-tripping master parameters, persistence strictly costs
    /// SSD time over the in-place host update, and both optimizer modes
    /// build and run with it.
    #[test]
    fn param_persist_adds_ssd_round_trips() {
        let sp = sp();
        let x = StorageRatios::ALL_CPU;
        let base = simulate_dist(&sp, 16, gs(x), cfg(2, 1)).t_iter;
        let pp =
            simulate_dist(&sp, 16, gs(x), DistConfig { param_persist: true, ..cfg(2, 1) })
                .t_iter;
        assert!(
            pp > base * 1.01,
            "param persistence {pp} must cost SSD time over host-resident {base}"
        );
        for shard in [false, true] {
            let c = DistConfig { param_persist: true, shard_optimizer: shard, ..cfg(2, 2) };
            let r = simulate_dist(&sp, 8, gs(x), c);
            assert!(r.t_iter.is_finite() && r.t_iter > 0.0, "shard={shard}");
        }
    }

    /// The dist sim's DRAM-cache mirror: a fitting cache serves the
    /// SSD-resident state from DRAM and strictly beats the uncached run on
    /// a shared contended SSD; a too-small cache changes nothing.
    #[test]
    fn cache_tier_absorbs_in_dist_sim() {
        let sp = sp();
        let sched = Schedule::GreedySnake { alpha: 0.0, x: StorageRatios::ALL_SSD };
        let none = simulate_dist(&sp, 16, sched, cfg(2, 1)).t_iter;
        let tiny =
            simulate_dist(&sp, 16, sched, DistConfig { cache_bytes: 1 << 20, ..cfg(2, 1) })
                .t_iter;
        assert_eq!(tiny, none, "a 1 MiB cache absorbs nothing here");
        let huge =
            simulate_dist(&sp, 16, sched, DistConfig { cache_bytes: u64::MAX, ..cfg(2, 1) })
                .t_iter;
        assert!(
            huge < 0.99 * none,
            "fitting cache {huge} must beat the SSD-bound dist run {none}"
        );
    }

    /// The `--precision` mirror on the dist sim: `ByteMults::ONE` is the
    /// default (identity), and the mixed-precision multipliers strictly
    /// beat strict f32's 2× wire widths on a shared contended SSD.
    #[test]
    fn byte_mults_scale_dist_sim() {
        use crate::memory::codec::Precision;
        let sp = sp();
        let sched = gs(StorageRatios::ALL_SSD);
        let default_ = simulate_dist(&sp, 16, sched, cfg(2, 1)).t_iter;
        let one = simulate_dist(
            &sp,
            16,
            sched,
            DistConfig { byte_mults: ByteMults::ONE, ..cfg(2, 1) },
        )
        .t_iter;
        assert_eq!(one, default_, "ByteMults::ONE is the default identity");
        let strict = simulate_dist(
            &sp,
            16,
            sched,
            DistConfig { byte_mults: ByteMults::for_precision(Precision::F32), ..cfg(2, 1) },
        )
        .t_iter;
        let mixed = simulate_dist(
            &sp,
            16,
            sched,
            DistConfig {
                byte_mults: ByteMults::for_precision(Precision::MixedF16),
                ..cfg(2, 1)
            },
        )
        .t_iter;
        assert!(mixed < strict, "mixed {mixed} must beat strict f32 {strict}");
    }

    /// The interconnect is a first-class resource: starving it slows the
    /// multi-worker iteration, and the single-worker pipeline (no ring)
    /// does not care.
    #[test]
    fn link_bandwidth_is_a_real_resource() {
        let mut slow_mach = MACHINE2_A100;
        slow_mach.link_bw = 2.0e8; // 0.2 GB/s: the ring becomes the bottleneck
        let mut model = GPT_65B;
        model.n_layers = 8;
        let fast = SystemParams::new(MACHINE2_A100.with_gpus(1), model, 2, SEQ_LEN);
        let slow = SystemParams::new(slow_mach.with_gpus(1), model, 2, SEQ_LEN);
        let x = StorageRatios::ALL_CPU;
        let t_fast = simulate_dist(&fast, 16, gs(x), cfg(2, 1)).t_iter;
        let t_slow = simulate_dist(&slow, 16, gs(x), cfg(2, 1)).t_iter;
        assert!(
            t_slow > t_fast * 1.05,
            "throttled link {t_slow} must cost more than NVLink {t_fast}"
        );
        let s_fast = simulate_dist(&fast, 16, gs(x), cfg(1, 1)).t_iter;
        let s_slow = simulate_dist(&slow, 16, gs(x), cfg(1, 1)).t_iter;
        assert!(
            (s_fast - s_slow).abs() <= 1e-9 * s_fast.max(1.0),
            "W=1 has no ring: {s_fast} vs {s_slow}"
        );
    }
}
