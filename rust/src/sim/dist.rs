//! Multi-worker (data-parallel) discrete-event simulation — the
//! `--workers W` mirror of [`crate::coordinator::dist::DataParallelEngine`].
//!
//! W workers each get their own compute resources (GPU, H2D, D2H lanes) but
//! share `ssds` SSD read/write resource pairs (workers are assigned
//! round-robin), so contention on the shared tier — the effect MLP-Offload
//! (arXiv 2509.02480) shows dominates multi-worker offloaded scaling — is
//! modeled rather than assumed away. The iteration structure matches the
//! runtime engine:
//!
//! * each worker runs its contiguous micro-batch share through the
//!   schedule's traversal (the visit order restricted to its share, grouped
//!   into per-layer spans), parameters reloading per span exactly like the
//!   runtime's one-layer cache, gated by the per-worker `--io-depth`
//!   lookahead window;
//! * fully-accumulated per-layer gradients leave each worker once
//!   (D2H, fp32), then a ring all-reduce joins all workers — modeled as one
//!   barrier-dependent op per worker moving 2·(W−1)/W·g over its PCIe lane;
//! * the optimizer runs ONCE per layer (rank 0's CPU + rank 0's SSD pair
//!   for the moment round trips), and every worker's next-iteration load of
//!   that layer waits on it — the cross-worker "update before forward"
//!   dependency.
//!
//! The delayed-α split is not modeled here (α = 0 semantics, like the
//! single-worker chunked builder): the multi-worker question this answers
//! is shared-SSD scaling, which the fig12 scaling bench
//! (`bench_out/fig12_scaling.json`) sweeps over W ∈ {1, 2, 4}.

use crate::coordinator::dist::partition;
use crate::coordinator::schedule::{
    ChunkedVerticalSchedule, HorizontalSchedule, Schedule as Traversal, VerticalSchedule,
};
use crate::perfmodel::{StorageRatios, SystemParams};

use super::engine::{DiscreteSim, Resource};
use super::schedules::{IoGate, Schedule, SimResult};

/// Simulate `m` GLOBAL micro-batches per iteration, split contiguously
/// across `workers` data-parallel workers sharing `ssds` SSDs. `io_depth`
/// is the per-worker lookahead window (`usize::MAX` = unbounded).
/// `workers == 1, ssds == 1` is the degenerate single-worker pipeline.
pub fn simulate_dist(
    sp: &SystemParams,
    m: u64,
    schedule: Schedule,
    io_depth: usize,
    workers: usize,
    ssds: usize,
) -> SimResult {
    let iters = 3;
    let (mk_all, busy_all) = build_and_run(sp, m, schedule, iters, io_depth, workers, ssds);
    let (mk_warm, _) = build_and_run(sp, m, schedule, iters - 1, io_depth, workers, ssds);
    let t_iter = (mk_all - mk_warm).max(1e-9);
    let w = workers.max(1) as f64;
    let tokens = (m * sp.micro_batch * sp.seq_len) as f64;
    let flops = sp.model.iter_flops(sp.micro_batch, sp.seq_len, m);
    SimResult {
        t_iter,
        tokens_per_s: tokens / t_iter,
        tflops_per_gpu: flops / w / t_iter / 1e12,
        gpu_util: (busy_all / w / iters as f64 / t_iter).min(1.0),
    }
}

/// Storage ratios the schedule implies (the dist builder needs only x; the
/// horizontal baselines use their heuristic placement).
fn ratios_of(sp: &SystemParams, m: u64, schedule: Schedule) -> StorageRatios {
    match schedule {
        Schedule::GreedySnake { x, .. } | Schedule::ChunkedVertical { x, .. } => x,
        Schedule::ZeroInfinity | Schedule::TeraIo | Schedule::Ratel => {
            sp.zero_infinity_placement(m).x
        }
    }
}

/// The runtime traversal policy this system's schedule corresponds to
/// (Ratel has no runtime analog; its single pass is closest to horizontal).
fn traversal_of(schedule: Schedule) -> Box<dyn Traversal> {
    match schedule {
        Schedule::GreedySnake { .. } => Box::new(VerticalSchedule),
        Schedule::ZeroInfinity | Schedule::TeraIo | Schedule::Ratel => {
            Box::new(HorizontalSchedule)
        }
        Schedule::ChunkedVertical { group, .. } => {
            Box::new(ChunkedVerticalSchedule::new(group as usize))
        }
    }
}

/// Consecutive same-layer visits of a restricted order: `(layer, count)` —
/// exactly the granularity at which the runtime's one-layer parameter cache
/// reloads.
type Spans = Vec<(usize, u64)>;

/// One forward span's checkpoint ops: (D2H op, optional SSD-write op).
type CkptOps = (usize, Option<usize>);

/// Group a (restricted) visit order into per-layer spans.
fn spans(order: &[(usize, usize)]) -> Spans {
    let mut out: Spans = Vec::new();
    for &(l, _) in order {
        match out.last_mut() {
            Some((pl, count)) if *pl == l => *count += 1,
            _ => out.push((l, 1)),
        }
    }
    out
}

fn build_and_run(
    sp: &SystemParams,
    m: u64,
    schedule: Schedule,
    iters: u32,
    io_depth: usize,
    workers: usize,
    ssds: usize,
) -> (f64, f64) {
    let w_n = workers.max(1);
    let s_n = ssds.max(1);
    // layout: per worker [gpu, h2d, d2h], then per ssd [read, write], then
    // the rank-0 optimizer CPU
    let n_res = 3 * w_n + 2 * s_n + 1;
    let gpu = |w: usize| Resource(3 * w);
    let h2d = |w: usize| Resource(3 * w + 1);
    let d2h = |w: usize| Resource(3 * w + 2);
    let ssd_r = |w: usize| Resource(3 * w_n + 2 * (w % s_n));
    let ssd_w = |w: usize| Resource(3 * w_n + 2 * (w % s_n) + 1);
    let cpu = Resource(3 * w_n + 2 * s_n);
    let mut sim = DiscreteSim::new(n_res);

    let x = ratios_of(sp, m, schedule);
    let policy = traversal_of(schedule);
    let n = sp.model.n_layers as usize;
    // each modeled SSD provides the node's full bandwidth (sharing between
    // workers is explicit through the resource, not a rate divisor)
    let (r, wbw, pcie) =
        (sp.node.ssd_read_bw(), sp.node.ssd_write_bw(), sp.node.pcie_bw_per_gpu());
    let (p, g, o, c) = (sp.p_lp(), sp.g_fp(), sp.o_bytes(), sp.c_bytes());

    let parts = partition(m as usize, w_n);
    let active: Vec<usize> = (0..w_n).filter(|&w| !parts[w].is_empty()).collect();
    let fwd_full = policy.forward_order(n, m as usize);
    let bwd_full = policy.backward_order(n, m as usize);
    let worker_spans: Vec<(Spans, Spans)> = parts
        .iter()
        .map(|range| {
            let f: Vec<(usize, usize)> =
                fwd_full.iter().copied().filter(|&(_, j)| range.contains(&j)).collect();
            let b: Vec<(usize, usize)> =
                bwd_full.iter().copied().filter(|&(_, j)| range.contains(&j)).collect();
            (spans(&f), spans(&b))
        })
        .collect();

    let ring_frac = if active.len() > 1 {
        2.0 * (active.len() as f64 - 1.0) / active.len() as f64
    } else {
        0.0
    };
    let mut gates: Vec<IoGate> = (0..w_n).map(|_| IoGate::new(io_depth)).collect();
    // per-layer optimizer op of the previous iteration (shared: rank 0
    // updates once; every worker's next load waits on it)
    let mut prev_adam: Vec<Option<usize>> = vec![None; n];
    // each worker's GPU is one serial stream across the whole run
    let mut last_gpu: Vec<Option<usize>> = vec![None; w_n];

    for _it in 0..iters {
        // fwd_ckpt[w][l] = the layer's checkpoint ops per span, in span order
        let mut fwd_ckpt: Vec<Vec<Vec<CkptOps>>> = vec![vec![Vec::new(); n]; w_n];
        // -------- forward, per worker --------------------------------------
        for &w in &active {
            for &(l, span) in &worker_spans[w].0 {
                let mut pdeps: Vec<usize> = gates[w].gate();
                if let Some(ad) = prev_adam[l] {
                    pdeps.push(ad); // cross-worker "update before forward"
                }
                let prd = sim.op(ssd_r(w), (1.0 - x.param_cpu) * p / r, &pdeps);
                let ph2d = sim.op(h2d(w), p / pcie, &[prd]);
                let mut deps = vec![ph2d];
                if let Some(lg) = last_gpu[w] {
                    deps.push(lg);
                }
                let f = sim.op(gpu(w), span as f64 * sp.t_fwd_mb(), &deps);
                last_gpu[w] = Some(f);
                gates[w].loaded(f);
                let dc = sim.op(d2h(w), span as f64 * c / pcie, &[f]);
                let wop = if x.ckpt_cpu < 1.0 {
                    Some(sim.op(ssd_w(w), (1.0 - x.ckpt_cpu) * span as f64 * c / wbw, &[dc]))
                } else {
                    None
                };
                fwd_ckpt[w][l].push((dc, wop));
            }
            gates[w].barrier(); // lookahead never crosses the pass boundary
        }

        // -------- backward, per worker -------------------------------------
        let mut grad_off: Vec<Vec<Option<usize>>> = vec![vec![None; n]; w_n];
        for &w in &active {
            let mut used: Vec<usize> = vec![0; n];
            let mut remaining: Vec<u64> = vec![parts[w].len() as u64; n];
            for &(l, span) in &worker_spans[w].1 {
                let pdeps: Vec<usize> = gates[w].gate();
                let prd = sim.op(ssd_r(w), (1.0 - x.param_cpu) * p / r, &pdeps);
                let ph2d = sim.op(h2d(w), p / pcie, &[prd]);
                // the span's input checkpoints back in (SSD share first);
                // backward spans of a layer arrive in the same order its
                // forward spans were produced for every traversal policy
                let (dc, wop) = fwd_ckpt[w][l][used[l]];
                used[l] += 1;
                let mut cdeps = vec![dc];
                if let Some(wo) = wop {
                    cdeps.push(sim.op(
                        ssd_r(w),
                        (1.0 - x.ckpt_cpu) * span as f64 * c / r,
                        &[wo],
                    ));
                }
                let hck = sim.op(h2d(w), span as f64 * c / pcie, &cdeps);
                let mut deps = vec![ph2d, hck];
                if let Some(lg) = last_gpu[w] {
                    deps.push(lg);
                }
                let b = sim.op(gpu(w), span as f64 * sp.t_bwd_mb(), &deps);
                last_gpu[w] = Some(b);
                gates[w].loaded(b);
                remaining[l] -= span;
                if remaining[l] == 0 {
                    // fully-accumulated gradients leave this worker once
                    grad_off[w][l] = Some(sim.op(d2h(w), g / pcie, &[b]));
                }
            }
            gates[w].barrier(); // the runtime flushes all lane I/O at step end
        }

        // -------- ring all-reduce + rank-0 optimizer, per layer ------------
        // Descending layer order, like the runtime's submission order.
        for l in (0..n).rev() {
            let offs: Vec<usize> = active
                .iter()
                .map(|&w| grad_off[w][l].expect("worker offloaded layer gradient"))
                .collect();
            // the ring is a barrier: every worker's legs depend on all
            // workers' offloads; each moves 2(W-1)/W·g over its PCIe lane
            let mut reduced: Vec<usize> = Vec::with_capacity(active.len());
            for &w in &active {
                reduced.push(sim.op(h2d(w), ring_frac * g / pcie, &offs));
            }
            let ord = sim.op(ssd_r(0), (1.0 - x.opt_cpu) * o / r, &[]);
            let mut adeps = reduced;
            adeps.push(ord);
            let ad = sim.op(cpu, sp.t_adam_layer(), &adeps);
            sim.op(
                ssd_w(0),
                ((1.0 - x.opt_cpu) * o + (1.0 - x.param_cpu) * p) / wbw,
                &[ad],
            );
            prev_adam[l] = Some(ad);
        }
    }

    let stats = sim.run();
    let gpu_busy: f64 = (0..w_n).map(|w| stats.busy[gpu(w).0]).sum();
    (stats.makespan, gpu_busy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MACHINE2_A100;
    use crate::modelcfg::{GPT_65B, SEQ_LEN};

    fn sp() -> SystemParams {
        let mut model = GPT_65B;
        model.n_layers = 8;
        SystemParams::new(MACHINE2_A100.with_gpus(1), model, 2, SEQ_LEN)
    }

    fn gs(x: StorageRatios) -> Schedule {
        Schedule::GreedySnake { alpha: 0.0, x }
    }

    /// The satellite contention property: two workers hammering ONE SSD are
    /// strictly slower than the same two workers over two modeled SSDs.
    #[test]
    fn shared_ssd_contention_slows_two_workers() {
        let sp = sp();
        let x = StorageRatios::ALL_SSD;
        let one = simulate_dist(&sp, 16, gs(x), usize::MAX, 2, 1).t_iter;
        let two = simulate_dist(&sp, 16, gs(x), usize::MAX, 2, 2).t_iter;
        assert!(
            one > two * 1.02,
            "one shared SSD {one} must cost more than two: {two}"
        );
    }

    /// The fig12-scaling property: with a quarter of the parameters on the
    /// one shared SSD, adding workers speeds the iteration up — each worker
    /// computes a smaller micro-batch share — but stays strictly
    /// sub-linear, because every worker re-reads the FULL parameter set
    /// from the shared device (total SSD traffic grows with W while
    /// compute shrinks).
    #[test]
    fn scaling_is_monotone_but_sublinear() {
        let sp = sp();
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.75, opt_cpu: 1.0 };
        let t1 = simulate_dist(&sp, 16, gs(x), usize::MAX, 1, 1).t_iter;
        let t2 = simulate_dist(&sp, 16, gs(x), usize::MAX, 2, 1).t_iter;
        let t4 = simulate_dist(&sp, 16, gs(x), usize::MAX, 4, 1).t_iter;
        assert!(t2 < t1, "W=2 {t2} must beat W=1 {t1}");
        assert!(t4 < t2, "W=4 {t4} must beat W=2 {t2}");
        assert!(
            t1 / t4 < 3.99,
            "W=4 speedup {} must be sub-linear under the shared SSD",
            t1 / t4
        );
    }

    /// The degenerate W=1 build is the same pipeline shape as the
    /// single-worker vertical builder — coarser (span-granular GPU ops, no
    /// boundary-micro-batch residency), but the same work totals, so the
    /// two agree within a small factor under a compute-dominated placement.
    #[test]
    fn w1_tracks_single_worker_sim() {
        let sp = sp();
        let x = StorageRatios::ALL_CPU;
        let dist = simulate_dist(&sp, 12, gs(x), usize::MAX, 1, 1).t_iter;
        let single =
            super::super::schedules::simulate(&sp, 12, Schedule::GreedySnake { alpha: 0.0, x })
                .t_iter;
        let ratio = dist / single;
        assert!(ratio > 0.5 && ratio < 2.0, "dist {dist} vs single {single}");
    }

    /// Tightening the per-worker lookahead window can only slow things down
    /// (same monotonicity the single-worker gate obeys).
    #[test]
    fn io_depth_gating_monotone_for_workers() {
        let sp = sp();
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        let sync = simulate_dist(&sp, 12, gs(x), 0, 2, 1).t_iter;
        let unbounded = simulate_dist(&sp, 12, gs(x), usize::MAX, 2, 1).t_iter;
        assert!(sync >= unbounded * 0.999, "sync {sync} vs unbounded {unbounded}");
    }

    /// All traversal policies run through the dist builder (spans differ,
    /// plumbing must not).
    #[test]
    fn all_schedules_build_and_run() {
        let sp = sp();
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        for s in [
            gs(x),
            Schedule::ZeroInfinity,
            Schedule::ChunkedVertical { group: 2, x },
        ] {
            for w in [1usize, 2, 3, 4] {
                let r = simulate_dist(&sp, 8, s, usize::MAX, w, 1);
                assert!(r.t_iter.is_finite() && r.t_iter > 0.0, "{s:?} W={w}");
                assert!(r.gpu_util > 0.0 && r.gpu_util <= 1.0, "{s:?} W={w}");
            }
        }
        // more workers than micro-batches: extras idle, still well-formed
        let r = simulate_dist(&sp, 2, gs(x), usize::MAX, 4, 2);
        assert!(r.t_iter.is_finite() && r.t_iter > 0.0);
    }
}
