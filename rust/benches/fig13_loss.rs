//! Figure 13 — training-loss equivalence: GreedySnake (vertical) vs
//! ZeRO-Infinity (horizontal) on the REAL stack — same model, same seed,
//! same data, PJRT-executed AOT artifacts, SSD-offloaded optimizer states.
//! The curves must coincide up to fp reordering noise (§6.5).

use greedysnake::coordinator::TrainerConfig;
use greedysnake::runtime::Manifest;
use greedysnake::trainer::{train, ScheduleKind};
use greedysnake::util::table::Table;

fn main() -> anyhow::Result<()> {
    let steps = 25u64;
    let m = 3usize;
    let mk_cfg = |tag: &str, alpha: f64| TrainerConfig {
        alpha,
        opt_on_ssd: true,
        ssd_path: std::env::temp_dir().join(format!("gs_fig13_{tag}_{}", std::process::id())),
        ..Default::default()
    };
    let v = train(
        Manifest::load("artifacts/tiny")?,
        mk_cfg("v", 0.25),
        ScheduleKind::Vertical,
        steps,
        m,
        0,
    )?;
    let h = train(
        Manifest::load("artifacts/tiny")?,
        mk_cfg("h", 0.0),
        ScheduleKind::Horizontal,
        steps,
        m,
        0,
    )?;

    let mut t = Table::new(
        "Fig. 13 — training loss, GreedySnake vs ZeRO-Infinity (real stack, tiny GPT)",
        &["step", "GreedySnake (vertical, α=0.25)", "ZeRO-Infinity (horizontal)", "|Δ|"],
    );
    for (i, (a, b)) in v.losses.iter().zip(&h.losses).enumerate() {
        t.row(&[
            i.to_string(),
            format!("{a:.4}"),
            format!("{b:.4}"),
            format!("{:.5}", (a - b).abs()),
        ]);
    }
    t.emit(Some("bench_out/fig13_loss.tsv"));

    let max_dev = v
        .losses
        .iter()
        .zip(&h.losses)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "max deviation {max_dev:.5}; final losses {:.4} vs {:.4} (paper: similar curves, minor fp discrepancies)",
        v.final_loss(),
        h.final_loss()
    );
    assert!(max_dev < 0.1, "schedules diverged");
    Ok(())
}
