//! Fig. 18 (serving panel) — the forward-only multi-tenant engine on the
//! phase-generic streaming core:
//!
//! * **simulated** (`sim::simulate_serve`, GPT-65B layer bytes): steady-state
//!   tokens/sec swept over (DRAM cache, SSD stripe count, tenant count T),
//!   each point checked against the [`serve_token_bound`] closed form and the
//!   fit-or-nothing cache absorption law — a working set (one shared base
//!   image + T adapter sets) that fits in cache drops the SSD stream to zero;
//! * **byte conservation** (stream-only runtime, no artifacts needed): the
//!   real `ServeEngine` decode counters must equal the
//!   `traffic::Workload::serve_*` closed forms EXACTLY — per token step,
//!   base-parameter bytes = ⌈B/G⌉ × model bytes for every schedule and every
//!   io-depth, and the uncached store moved exactly the metered bytes;
//! * **cache sharing**: serving T tenants through one `CachedStore` with
//!   per-tenant admission must hit the SAME cached base objects — parameter
//!   hits grow with T while parameter misses do not (the base is resident
//!   once, not per tenant);
//! * **real runtime** (when the AOT artifacts are built): real
//!   EmbedFwd/LayerFwd decode over the manifest model — deterministic token
//!   streams that differ across tenants, same byte law.
//!
//! Emits `bench_out/fig18_serve.json` (uploaded as a CI artifact) plus a
//! human-readable table.

use std::collections::BTreeMap;
use std::sync::Arc;

use greedysnake::coordinator::schedule::param_loads;
use greedysnake::coordinator::serve::{provision, ServeModel};
use greedysnake::coordinator::ServeEngine;
use greedysnake::memory::{
    CacheAdmission, CachedStore, Category, SsdStorage, TensorStore,
};
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::sim::{serve_token_bound, simulate_serve, ServeSimConfig};
use greedysnake::traffic::Workload;
use greedysnake::trainer::ScheduleKind;
use greedysnake::util::json::Json;
use greedysnake::util::stats::fmt_bytes;
use greedysnake::util::table::Table;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("gs_f18_{tag}_{}", std::process::id()))
}

fn main() {
    let mut report: BTreeMap<String, Json> = BTreeMap::new();

    // ---- sim sweep: tokens/sec vs (cache, ssds, tenants) -----------------
    let lanes = 4u64;
    let wl = Workload { model: GPT_65B, micro_batch: 2, seq_len: SEQ_LEN, m: lanes, shards: 1 };
    let base_cfg = ServeSimConfig {
        n_layers: GPT_65B.n_layers,
        layer_bytes: wl.ms_lp() as f64 / GPT_65B.n_layers as f64,
        embed_bytes: 64e6,
        compute_s_per_visit: 5e-3,
        lanes,
        group: u64::MAX, // vertical decode: each layer streamed once per step
        io_depth: 2,
        ssds: 1,
        cache_bytes: 0,
        working_set_bytes: 0,
        ssd_read_bps: 3e9,
        h2d_bps: 20e9,
    };
    let mut t = Table::new(
        "Fig. 18 (serving) — GPT-65B vertical decode, tokens/s vs cache / ssds / tenants",
        &["T", "ssds", "cache", "absorbed", "tok/s", "bound tok/s", "ssd B/token"],
    );
    let mut sweep: Vec<Json> = Vec::new();
    for tenants in [1u64, 2, 4, 8] {
        let ws = wl.serve_working_set_bytes(tenants, 64);
        for ssds in [1u64, 2, 4] {
            for cache_bytes in [0u64, ws] {
                let c = ServeSimConfig {
                    ssds,
                    cache_bytes,
                    working_set_bytes: ws,
                    ..base_cfg
                };
                let r = simulate_serve(&c);
                let bound = serve_token_bound(&c);
                assert!(
                    r.t_token_s >= bound * 0.999,
                    "T={tenants} N={ssds} cache={cache_bytes}: sim {} under bound {}",
                    r.t_token_s,
                    bound
                );
                // fit-or-nothing: a fitting cache removes the SSD stream
                assert_eq!(r.absorbed, cache_bytes >= ws && cache_bytes > 0);
                if r.absorbed {
                    assert_eq!(r.ssd_read_bytes_per_token, 0.0);
                }
                t.row(&[
                    tenants.to_string(),
                    ssds.to_string(),
                    if cache_bytes == 0 { "0".into() } else { fmt_bytes(cache_bytes as f64) },
                    r.absorbed.to_string(),
                    format!("{:.2}", r.tokens_per_s),
                    format!("{:.2}", c.lanes as f64 / bound),
                    fmt_bytes(r.ssd_read_bytes_per_token),
                ]);
                let mut o = BTreeMap::new();
                o.insert("tenants".into(), Json::Num(tenants as f64));
                o.insert("ssds".into(), Json::Num(ssds as f64));
                o.insert("cache_bytes".into(), Json::Num(cache_bytes as f64));
                o.insert("working_set_bytes".into(), Json::Num(ws as f64));
                o.insert("absorbed".into(), Json::Bool(r.absorbed));
                o.insert("tokens_per_s".into(), Json::Num(r.tokens_per_s));
                o.insert("bound_tokens_per_s".into(), Json::Num(c.lanes as f64 / bound));
                o.insert("ssd_bytes_per_token".into(), Json::Num(r.ssd_read_bytes_per_token));
                sweep.push(Json::Obj(o));
            }
        }
        // striping scales the uncached read bottleneck
        let t1 = simulate_serve(&ServeSimConfig { working_set_bytes: ws, ..base_cfg });
        let t4 = simulate_serve(&ServeSimConfig { ssds: 4, working_set_bytes: ws, ..base_cfg });
        assert!(t4.tokens_per_s > t1.tokens_per_s, "striping must help the SSD-bound decode");
    }
    t.emit(Some("bench_out/fig18_serve.tsv"));
    report.insert("sim_sweep".into(), Json::Arr(sweep));

    // the analytic serve forms are the forward leg of the training forms
    for g in [1u64, 4, 16, lanes] {
        assert_eq!(
            2 * wl.serve_param_read_bytes(g),
            wl.chunked_vertical(g).param_load,
            "g={g}: serve form is not the forward leg of chunked:{g}"
        );
    }

    // ---- byte conservation: runtime counters == closed forms -------------
    // stream-only decode (no artifacts needed): 6 lanes makes chunked:4
    // ragged, so the ⌈B/G⌉ ceiling is actually exercised
    let model = ServeModel::synthetic(4, 4096, 1024, 50257);
    let b_lanes = 6u64;
    let model_bytes = model.n_layers as u64 * model.base_layer_bytes();
    for (sched_name, g) in [("vertical", b_lanes), ("horizontal", 1), ("chunked:4", 4)] {
        let kind: ScheduleKind = sched_name.parse().expect("schedule grammar");
        let sched = kind.policy();
        for depth in [0usize, 2] {
            let store: Arc<dyn TensorStore> = Arc::new(
                SsdStorage::create_unthrottled(tmp(&format!("bytes_{g}_{depth}"))).unwrap(),
            );
            provision(store.as_ref(), &model, 2, 7).unwrap();
            let mut eng = ServeEngine::new(model.clone(), Arc::clone(&store), depth, 11);
            let batch = greedysnake::coordinator::serve::Batch {
                tenant: 1,
                requests: (0..b_lanes).collect(),
            };
            let tokens = 3usize;
            eng.decode(sched.as_ref(), &batch, tokens, None).unwrap();
            let s = eng.stats();
            let order = sched.forward_order(model.n_layers, b_lanes as usize);
            let tag = format!("{sched_name} depth={depth}");
            // per token step: N·⌈B/G⌉ loads, ⌈B/G⌉ × model bytes — the
            // serve_param_loads / serve_param_read_bytes forms verbatim
            let loads_per_step = model.n_layers as u64 * b_lanes.div_ceil(g);
            assert_eq!(param_loads(&order) as u64, loads_per_step, "{tag}: schedule count");
            assert_eq!(s.param_loads, loads_per_step * tokens as u64, "{tag}: loads");
            assert_eq!(
                s.base_bytes_loaded,
                b_lanes.div_ceil(g) * model_bytes * tokens as u64,
                "{tag}: base bytes off the closed form"
            );
            assert_eq!(
                s.adapter_bytes_loaded,
                s.param_loads * model.adapter_layer_bytes(),
                "{tag}: adapter bytes"
            );
            assert_eq!(
                s.store_bytes_read,
                s.base_bytes_loaded + s.adapter_bytes_loaded + s.embed_bytes_loaded,
                "{tag}: store moved bytes the meters missed"
            );
        }
    }
    println!("byte conservation: decode counters == serve closed forms (3 schedules x 2 depths)");
    report.insert("byte_conservation".into(), Json::Str("ok".into()));

    // ---- cache sharing: base hits grow with T, misses do not -------------
    let share_model = ServeModel::synthetic(2, 256, 64, 101);
    let share = |tenants: u64| {
        let dev = Arc::new(SsdStorage::create_unthrottled(tmp(&format!("share_{tenants}"))).unwrap());
        let store: Arc<dyn TensorStore> = Arc::new(CachedStore::with_admission(
            dev,
            1 << 20,
            CacheAdmission::PerTenant { per_tenant_bytes: 1 << 16 },
        ));
        provision(store.as_ref(), &share_model, tenants, 9).unwrap();
        let mut eng = ServeEngine::new(share_model.clone(), Arc::clone(&store), 0, 1);
        for tenant in 0..tenants {
            let b = greedysnake::coordinator::serve::Batch { tenant, requests: vec![0, 1] };
            eng.decode(&greedysnake::coordinator::VerticalSchedule, &b, 2, None).unwrap();
        }
        store
            .cache_stats()
            .by_cat
            .get(&Category::Parameters)
            .cloned()
            .unwrap_or_default()
    };
    let p1 = share(1);
    let p4 = share(4);
    assert!(
        p4.hits > p1.hits,
        "shared base hits must grow with tenants: T=1 {} vs T=4 {}",
        p1.hits,
        p4.hits
    );
    assert_eq!(
        p1.misses, p4.misses,
        "the base image is resident once, not once per tenant"
    );
    println!(
        "cache sharing: base hits {} (T=1) -> {} (T=4), misses {} == {}",
        p1.hits, p4.hits, p1.misses, p4.misses
    );
    let mut cs = BTreeMap::new();
    cs.insert("base_hits_t1".into(), Json::Num(p1.hits as f64));
    cs.insert("base_hits_t4".into(), Json::Num(p4.hits as f64));
    cs.insert("base_misses_t1".into(), Json::Num(p1.misses as f64));
    cs.insert("base_misses_t4".into(), Json::Num(p4.misses as f64));
    report.insert("cache_sharing".into(), Json::Obj(cs));

    // ---- real-runtime decode leg (skips without AOT artifacts) -----------
    let runtime_status = match greedysnake::runtime::test_artifacts("artifacts/tiny") {
        None => {
            println!("runtime decode: skipped (artifacts/tiny not built)");
            "skipped".to_string()
        }
        Some(manifest) => {
            let rt = greedysnake::runtime::Runtime::load(&manifest).unwrap();
            let model = ServeModel::from_manifest(&manifest);
            let store: Arc<dyn TensorStore> =
                Arc::new(SsdStorage::create_unthrottled(tmp("rt")).unwrap());
            provision(store.as_ref(), &model, 2, 5).unwrap();
            let decode = |tenant: u64, seed: u64| {
                let mut eng = ServeEngine::new(model.clone(), Arc::clone(&store), 2, seed);
                let b = greedysnake::coordinator::serve::Batch { tenant, requests: vec![0, 1] };
                let toks = eng
                    .decode(&greedysnake::coordinator::VerticalSchedule, &b, 2, Some(&rt))
                    .unwrap();
                (toks, eng.stats())
            };
            let (a, s) = decode(0, 42);
            let (b, _) = decode(0, 42);
            let (c, _) = decode(1, 42);
            assert_eq!(a, b, "real-compute decode must be deterministic");
            assert_ne!(a, c, "tenant adapters must steer the real token stream");
            // the byte law holds under real compute too (vertical: ⌈B/G⌉=1)
            assert_eq!(
                s.base_bytes_loaded,
                2 * model.n_layers as u64 * model.base_layer_bytes(),
                "real-compute decode broke the byte law"
            );
            println!("runtime decode: deterministic, tenant-steered, byte law holds");
            "ok".to_string()
        }
    };
    report.insert("runtime_decode".into(), Json::Str(runtime_status));

    std::fs::create_dir_all("bench_out").expect("create bench_out");
    let path = "bench_out/fig18_serve.json";
    std::fs::write(path, Json::Obj(report).to_string_compact()).expect("write serve report");
    println!("serve report -> {path}");
}
