//! Figure 4 — batch-size scaling in the single forward-backward schedule
//! (GPT-65B): max achievable batch and checkpoint traffic for per-layer vs
//! attention/FFN checkpointing. Reproduces the §3.2 arithmetic: extra
//! checkpoints buy ~1.5× batch at ~3× checkpoint traffic.

use greedysnake::machine::MACHINE2_A100;
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::SystemParams;
use greedysnake::traffic::Workload;
use greedysnake::util::stats::fmt_bytes;
use greedysnake::util::table::Table;

fn main() {
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    let b_plain = sp.single_pass_max_batch(false);
    let b_extra = sp.single_pass_max_batch(true);

    let mut t = Table::new(
        "Fig. 4 — single-pass batch scaling, GPT-65B (A100 40 GB)",
        &["checkpointing", "max batch", "ckpt traffic/iter", "throughput tok/s"],
    );
    for (label, batch, extra) in [
        ("per-layer", b_plain, false),
        ("+attn/FFN boundary", b_extra, true),
    ] {
        let wl = Workload { model: GPT_65B, micro_batch: batch, seq_len: SEQ_LEN, m: 1, shards: 1 };
        let traffic = wl.single_pass(extra);
        let est = sp.single_pass_iter(batch, extra);
        t.row(&[
            label.into(),
            batch.to_string(),
            fmt_bytes((traffic.ckpt_load + traffic.ckpt_store) as f64),
            format!("{:.1}", est.tokens_per_s),
        ]);
    }
    t.emit(Some("bench_out/fig04_single_pass.tsv"));

    let ratio_batch = b_extra as f64 / b_plain as f64;
    let t_plain = Workload { model: GPT_65B, micro_batch: b_plain, seq_len: SEQ_LEN, m: 1, shards: 1 }
        .single_pass(false);
    let t_extra = Workload { model: GPT_65B, micro_batch: b_extra, seq_len: SEQ_LEN, m: 1, shards: 1 }
        .single_pass(true);
    let ratio_traffic =
        (t_extra.ckpt_load + t_extra.ckpt_store) as f64 / (t_plain.ckpt_load + t_plain.ckpt_store) as f64;
    println!(
        "extra checkpoints: {ratio_batch:.2}x batch (paper ~1.5x) at {ratio_traffic:.2}x ckpt traffic (paper ~3x)"
    );
}
