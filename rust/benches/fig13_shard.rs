//! Fig. 13 (sharded-optimizer panel) — ZeRO-style sharded optimizer states
//! vs the rank-0 optimizer at W ∈ {1, 2, 4}:
//!
//! * **simulated** (GPT-65B on the A100 node, `sim::simulate_dist`):
//!   reduce-scatter + per-rank 1/W update + parameter all-gather against
//!   the full rank-0 update, per-worker interconnect legs and SSD pairs;
//! * **closed forms** (`traffic::Workload`): per-rank optimizer SSD round
//!   trips — the acceptance property is that they scale ~1/W under
//!   `--shard-optimizer` while the rank-0 path is W-invariant — plus the
//!   reduce-scatter / all-gather ring totals;
//! * **real runtime** (when the AOT artifacts are built): a short
//!   `--shard-optimizer --workers 2` run must be bit-identical to the
//!   `--workers 1` baseline (losses and Σx² parameter/moment digests).
//!
//! Emits `bench_out/fig13_shard.json` (uploaded as a CI artifact) plus a
//! human-readable table.

use std::collections::BTreeMap;

use greedysnake::coordinator::TrainerConfig;
use greedysnake::lp;
use greedysnake::machine::MACHINE2_A100;
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::{StorageRatios, SystemParams};
use greedysnake::sim::{simulate_dist, DistConfig, Schedule};
use greedysnake::traffic::Workload;
use greedysnake::trainer::{train, ScheduleKind};
use greedysnake::util::json::Json;
use greedysnake::util::table::Table;

fn main() {
    let m = 32u64;
    let alpha = 0.3;
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    let x = lp::solve_config(&sp, m, alpha)
        .map(|r| r.ratios)
        .unwrap_or(StorageRatios::ALL_SSD);
    let sched = Schedule::GreedySnake { alpha, x };
    let wl = Workload { model: GPT_65B, micro_batch: 2, seq_len: SEQ_LEN, m, shards: 1 };

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("model".to_string(), Json::Str("gpt-65b".to_string()));
    report.insert("machine".to_string(), Json::Str("a100".to_string()));
    report.insert("schedule".to_string(), Json::Str(sched.kind_name()));
    report.insert("m_global".to_string(), Json::Num(m as f64));
    report.insert("alpha".to_string(), Json::Num(alpha));

    let mut t = Table::new(
        "Fig. 13 (sharded optimizer) — GPT-65B A100, rank-0 vs ZeRO-style sharded",
        &[
            "W",
            "rank-0 tok/s",
            "sharded tok/s",
            "speedup",
            "opt SSD/rank (rank-0)",
            "opt SSD/rank (sharded)",
            "reduce-scatter",
            "all-gather",
        ],
    );
    let mut per_w: BTreeMap<String, Json> = BTreeMap::new();
    let full_rt = wl.opt_ssd_round_trip_bytes();
    for w in [1usize, 2, 4] {
        let base = DistConfig { workers: w, ssds: 1, ..DistConfig::default() };
        let rank0 = simulate_dist(&sp, m, sched, base);
        let sharded =
            simulate_dist(&sp, m, sched, DistConfig { shard_optimizer: true, ..base });
        let speedup = rank0.t_iter / sharded.t_iter;
        let per_rank = wl.sharded_opt_ssd_bytes_per_rank(w as u64);
        // the acceptance property: per-rank optimizer SSD bytes ~1/W
        assert!(
            per_rank <= full_rt / w as u64 + w as u64,
            "W={w}: per-rank opt bytes {per_rank} not ~1/W of {full_rt}"
        );
        t.row(&[
            w.to_string(),
            format!("{:.0}", rank0.tokens_per_s),
            format!("{:.0}", sharded.tokens_per_s),
            format!("{speedup:.2}x"),
            greedysnake::util::stats::fmt_bytes(full_rt as f64),
            greedysnake::util::stats::fmt_bytes(per_rank as f64),
            greedysnake::util::stats::fmt_bytes(wl.reduce_scatter_bytes_total(w as u64) as f64),
            greedysnake::util::stats::fmt_bytes(wl.allgather_bytes_total(w as u64) as f64),
        ]);
        let mut o = BTreeMap::new();
        o.insert("rank0_t_iter_s".to_string(), Json::Num(rank0.t_iter));
        o.insert("sharded_t_iter_s".to_string(), Json::Num(sharded.t_iter));
        o.insert("rank0_tokens_per_s".to_string(), Json::Num(rank0.tokens_per_s));
        o.insert("sharded_tokens_per_s".to_string(), Json::Num(sharded.tokens_per_s));
        o.insert("speedup_sharded_vs_rank0".to_string(), Json::Num(speedup));
        o.insert(
            "opt_ssd_bytes_per_rank_rank0".to_string(),
            Json::Num(full_rt as f64),
        );
        o.insert(
            "opt_ssd_bytes_per_rank_sharded".to_string(),
            Json::Num(per_rank as f64),
        );
        o.insert(
            "reduce_scatter_bytes_total".to_string(),
            Json::Num(wl.reduce_scatter_bytes_total(w as u64) as f64),
        );
        o.insert(
            "allgather_bytes_total".to_string(),
            Json::Num(wl.allgather_bytes_total(w as u64) as f64),
        );
        per_w.insert(w.to_string(), Json::Obj(o));
    }
    t.emit(Some("bench_out/fig13_shard.tsv"));
    report.insert("workers".to_string(), Json::Obj(per_w));
    println!(
        "per-rank optimizer SSD round trip: {} at W=1 -> {} at W=4 (~1/W)",
        greedysnake::util::stats::fmt_bytes(full_rt as f64),
        greedysnake::util::stats::fmt_bytes(wl.sharded_opt_ssd_bytes_per_rank(4) as f64),
    );

    // ---- real-runtime equivalence leg (skips without AOT artifacts) ------
    let runtime_status = match greedysnake::runtime::test_artifacts("artifacts/tiny") {
        None => {
            println!("runtime equivalence: skipped (artifacts/tiny not built)");
            "skipped".to_string()
        }
        Some(_) => {
            let mk = |tag: &str, workers: usize, shard: bool| TrainerConfig {
                alpha: 0.25,
                opt_on_ssd: true,
                workers,
                shard_optimizer: shard,
                ssd_path: std::env::temp_dir()
                    .join(format!("gs_f13sh_{tag}_{}", std::process::id())),
                ..Default::default()
            };
            let manifest = || greedysnake::runtime::Manifest::load("artifacts/tiny").unwrap();
            let base =
                train(manifest(), mk("w1", 1, false), ScheduleKind::Vertical, 6, 4, 0).unwrap();
            let sharded =
                train(manifest(), mk("w2s", 2, true), ScheduleKind::Vertical, 6, 4, 0).unwrap();
            assert_eq!(base.losses, sharded.losses, "sharded losses diverged");
            assert_eq!(
                base.param_sq_norm.to_bits(),
                sharded.param_sq_norm.to_bits(),
                "sharded parameters diverged"
            );
            assert_eq!(
                base.moment_sq_norm.to_bits(),
                sharded.moment_sq_norm.to_bits(),
                "sharded optimizer moments diverged"
            );
            assert!(sharded.allgather_bytes > 0, "sharded run gathered nothing");
            println!(
                "runtime equivalence: W=2 sharded bit-identical to W=1 \
                 (reduce-scatter {}, all-gather {})",
                greedysnake::util::stats::fmt_bytes(sharded.allreduce_bytes as f64),
                greedysnake::util::stats::fmt_bytes(sharded.allgather_bytes as f64),
            );
            "ok".to_string()
        }
    };
    report.insert("runtime_equivalence".to_string(), Json::Str(runtime_status));

    std::fs::create_dir_all("bench_out").expect("create bench_out");
    let path = "bench_out/fig13_shard.json";
    std::fs::write(path, Json::Obj(report).to_string_compact()).expect("write shard report");
    println!("shard report -> {path}");
}
