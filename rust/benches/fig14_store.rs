//! Fig. 14 (storage-tier panel) — the pluggable TensorStore backends under
//! a throttled SSD: single device vs striped-2 vs DRAM-cached.
//!
//! * **simulated** (GPT-65B on the A100 node, `sim::simulate_store`): an
//!   SSD-bound placement (everything offloaded) with 1 vs 2 striped
//!   devices (2× aggregate bandwidth) and with a fitting DRAM cache
//!   (fit-or-nothing absorption → the ALL_CPU placement);
//! * **closed forms** (`traffic::Workload`): the SSD-resident working set,
//!   the runtime store's per-iteration byte counters, and the cached
//!   residual (0 when the working set fits, full traffic when not);
//! * **real runtime** (when the AOT artifacts are built): short throttled
//!   runs through each backend must be bit-identical (losses + Σx²
//!   digests), striped-2 must strictly reduce wall-clock, and the cached
//!   run's measured `ssd_read` must equal the closed form's residual
//!   EXACTLY (zero — every get is a DRAM hit).
//!
//! Emits `bench_out/fig14_store.json` (uploaded as a CI artifact) plus a
//! human-readable table.

use std::collections::BTreeMap;

use greedysnake::coordinator::TrainerConfig;
use greedysnake::machine::MACHINE2_A100;
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::{StorageRatios, SystemParams};
use greedysnake::sim::{simulate_store, Schedule};
use greedysnake::traffic::Workload;
use greedysnake::trainer::{train, RunLog, ScheduleKind};
use greedysnake::util::json::Json;
use greedysnake::util::table::Table;

fn main() {
    let m = 16u64;
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    let x = StorageRatios::ALL_SSD; // the storage tier IS the bottleneck
    let sched = Schedule::GreedySnake { alpha: 0.0, x };
    let wl = Workload { model: GPT_65B, micro_batch: 2, seq_len: SEQ_LEN, m, shards: 1 };

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("model".to_string(), Json::Str("gpt-65b".to_string()));
    report.insert("machine".to_string(), Json::Str("a100".to_string()));
    report.insert("schedule".to_string(), Json::Str(sched.kind_name()));
    report.insert("m".to_string(), Json::Num(m as f64));

    // ---- sim sweep --------------------------------------------------------
    let ws = wl.ssd_working_set_bytes(x.param_cpu, x.ckpt_cpu, x.opt_cpu);
    let single = simulate_store(&sp, m, sched, usize::MAX, 1, 0);
    let striped = simulate_store(&sp, m, sched, usize::MAX, 2, 0);
    let cached = simulate_store(&sp, m, sched, usize::MAX, 1, ws);
    assert!(
        striped.t_iter < single.t_iter,
        "striped-2 sim {} must beat single {}",
        striped.t_iter,
        single.t_iter
    );
    assert!(
        cached.t_iter < single.t_iter,
        "fitting cache sim {} must beat single {}",
        cached.t_iter,
        single.t_iter
    );
    let mut t = Table::new(
        "Fig. 14 (storage tier) — GPT-65B A100, all-SSD placement",
        &["backend", "t_iter (s)", "tokens/s", "speedup vs single"],
    );
    let mut sim_obj: BTreeMap<String, Json> = BTreeMap::new();
    for (name, r) in
        [("single-ssd", single), ("striped-2", striped), ("dram-cached", cached)]
    {
        t.row(&[
            name.to_string(),
            format!("{:.2}", r.t_iter),
            format!("{:.0}", r.tokens_per_s),
            format!("{:.2}x", single.t_iter / r.t_iter),
        ]);
        let mut o = BTreeMap::new();
        o.insert("t_iter_s".to_string(), Json::Num(r.t_iter));
        o.insert("tokens_per_s".to_string(), Json::Num(r.tokens_per_s));
        o.insert(
            "speedup_vs_single".to_string(),
            Json::Num(single.t_iter / r.t_iter),
        );
        sim_obj.insert(name.to_string(), Json::Obj(o));
    }
    t.emit(Some("bench_out/fig14_store.tsv"));
    report.insert("sim".to_string(), Json::Obj(sim_obj));

    // ---- closed forms -----------------------------------------------------
    let mut forms: BTreeMap<String, Json> = BTreeMap::new();
    forms.insert("ssd_working_set_bytes".to_string(), Json::Num(ws as f64));
    forms.insert(
        "store_read_bytes_per_iter".to_string(),
        Json::Num(wl.store_read_bytes(true, true) as f64),
    );
    forms.insert(
        "cached_residual_fitting".to_string(),
        Json::Num(wl.cached_store_read_bytes(
            true,
            true,
            wl.store_working_set_bytes(true, true),
        ) as f64),
    );
    forms.insert(
        "cached_residual_undersized".to_string(),
        Json::Num(wl.cached_store_read_bytes(true, true, 1) as f64),
    );
    // the fit-or-nothing law in numbers
    assert_eq!(
        wl.cached_store_read_bytes(true, true, wl.store_working_set_bytes(true, true)),
        0
    );
    assert_eq!(
        wl.cached_store_read_bytes(true, true, 1),
        wl.store_read_bytes(true, true)
    );
    report.insert("closed_forms".to_string(), Json::Obj(forms));
    println!(
        "closed forms: working set {}, per-iter store reads {}",
        greedysnake::util::stats::fmt_bytes(ws as f64),
        greedysnake::util::stats::fmt_bytes(wl.store_read_bytes(true, true) as f64),
    );

    // ---- real-runtime leg (skips without AOT artifacts) -------------------
    let runtime_status = match greedysnake::runtime::test_artifacts("artifacts/tiny") {
        None => {
            println!("runtime store leg: skipped (artifacts/tiny not built)");
            "skipped".to_string()
        }
        Some(_) => {
            let mk = |tag: &str, ssds: usize, cache_mb: usize| TrainerConfig {
                alpha: 0.0,
                opt_on_ssd: true,
                ckpt_on_ssd: true,
                overlap: false,
                io_depth: 0,
                ssd_read_bps: 4e6,
                ssd_write_bps: 4e6,
                ssds,
                cpu_cache_mb: cache_mb,
                ssd_path: std::env::temp_dir()
                    .join(format!("gs_f14_{tag}_{}", std::process::id())),
                ..Default::default()
            };
            let manifest = || greedysnake::runtime::Manifest::load("artifacts/tiny").unwrap();
            let go = |tag: &str, ssds: usize, cache_mb: usize| -> RunLog {
                train(manifest(), mk(tag, ssds, cache_mb), ScheduleKind::Vertical, 3, 3, 0)
                    .unwrap()
            };
            let single = go("s1", 1, 0);
            let striped = go("s2", 2, 0);
            // unthrottled-equivalent cache run: no SSD traffic to throttle
            let cached = go("c", 1, 256);
            for (name, log) in [("striped-2", &striped), ("cached", &cached)] {
                assert_eq!(single.losses, log.losses, "{name}: losses diverged");
                assert_eq!(
                    single.param_sq_norm.to_bits(),
                    log.param_sq_norm.to_bits(),
                    "{name}: parameters diverged"
                );
                assert_eq!(
                    single.moment_sq_norm.to_bits(),
                    log.moment_sq_norm.to_bits(),
                    "{name}: moments diverged"
                );
            }
            let t1: f64 = single.step_seconds.iter().sum();
            let t2: f64 = striped.step_seconds.iter().sum();
            assert!(
                t2 < t1,
                "striped-2 runtime {t2:.3}s must strictly undercut single {t1:.3}s"
            );
            // the closed form matches the measured counters EXACTLY
            assert!(single.ssd_read > 0);
            assert_eq!(
                cached.ssd_read, 0,
                "fitting cache: measured residual must equal the closed form (0)"
            );
            assert_eq!(cached.ssd_written, 0);
            let mut o = BTreeMap::new();
            o.insert("single_wall_s".to_string(), Json::Num(t1));
            o.insert("striped2_wall_s".to_string(), Json::Num(t2));
            o.insert(
                "single_ssd_read_bytes".to_string(),
                Json::Num(single.ssd_read as f64),
            );
            o.insert(
                "cached_ssd_read_bytes".to_string(),
                Json::Num(cached.ssd_read as f64),
            );
            o.insert("cache_hits".to_string(), Json::Num(cached.cache_hits as f64));
            report.insert("runtime".to_string(), Json::Obj(o));
            println!(
                "runtime store leg: single {t1:.2}s vs striped-2 {t2:.2}s; \
                 cached ssd reads {} (closed form: 0)",
                cached.ssd_read,
            );
            "ok".to_string()
        }
    };
    report.insert("runtime_status".to_string(), Json::Str(runtime_status));

    std::fs::create_dir_all("bench_out").expect("create bench_out");
    let path = "bench_out/fig14_store.json";
    std::fs::write(path, Json::Obj(report).to_string_compact()).expect("write store report");
    println!("store report -> {path}");
}
