//! Figure 3 — the roofline model of SSD-offloaded training.
//! Prints the I/O-access line, the compute line, and the ideal envelope for
//! GPT-65B on the A100 node (tokens/s vs batch size).

use greedysnake::machine::MACHINE2_A100;
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::roofline::Roofline;
use greedysnake::util::table::Table;

fn main() {
    let r = Roofline {
        node: MACHINE2_A100.with_gpus(1),
        model: GPT_65B,
        micro_batch: 2,
        seq_len: SEQ_LEN,
    };
    let mut t = Table::new(
        "Fig. 3 — roofline, GPT-65B on A100-node (tokens/s)",
        &["global batch", "I/O roofline", "compute roofline", "ideal envelope"],
    );
    for m in [1u64, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128] {
        t.row(&[
            (m * 2).to_string(),
            format!("{:.1}", r.io_bound_tokens_per_s(m)),
            format!("{:.1}", r.compute_bound_tokens_per_s()),
            format!("{:.1}", r.ideal_tokens_per_s(m)),
        ]);
    }
    t.emit(Some("bench_out/fig03_roofline.tsv"));
    println!(
        "optimizer-state SSD round trip: {:.0}s/iter; ideal knee at global batch ≈ {:.0}",
        r.t_io_opt_states(),
        r.knee_m() * 2.0
    );
}
