//! Fig. 19 (device model + autotuner panel) — the QD-aware NVMe device
//! model, io_uring-style submission batching, and the sim-driven
//! `autotune` subcommand:
//!
//! * **device curve** (`DeviceProfile::eff_bps`): effective bandwidth over
//!   queue depth × request size for a profiled device — small requests pay
//!   the per-op latency floor, shallow queues leave the QD ramp unclimbed;
//! * **real batching measurement** (always runs — no AOT artifacts
//!   needed): 64 KiB objects through `SsdStorage` on a latency-floored
//!   device, 8 concurrent submitters; the `--io-batch` ring window must
//!   deliver **>= 1.5x** small-object throughput over unbatched (the
//!   acceptance bar), with byte counters and contents bit-identical;
//! * **autotune vs hand-picked defaults** (sim): for two memory-starved
//!   (hardware × model) pairs the coordinate-descent tuner must strictly
//!   beat the conventional default knobs.
//!
//! Emits `bench_out/fig19_autotune.json` (uploaded as a CI artifact) plus
//! a human-readable table.

use std::collections::BTreeMap;
use std::time::Instant;

use greedysnake::autotune::{autotune, default_knobs, eval_knobs, HwProfile};
use greedysnake::machine::{Machine, GIB, MACHINE1_A5000, MACHINE2_A100};
use greedysnake::memory::{BatchConfig, DeviceProfile, SsdStorage};
use greedysnake::modelcfg::{ModelCfg, GPT_30B, GPT_65B};
use greedysnake::util::json::Json;
use greedysnake::util::table::Table;

/// The profiled device the batching measurement runs on: infinite stream
/// bandwidth so ONLY the per-op latency floor is priced — exactly the
/// regime submission batching amortizes.
fn bench_device() -> DeviceProfile {
    DeviceProfile {
        read_bps: f64::INFINITY,
        write_bps: f64::INFINITY,
        qd_knee: 4,
        sat_bytes: 1 << 20,
        mix_penalty: 0.0,
        op_latency_s: 200e-6,
    }
}

/// 8 submitters × `ops` puts then `ops` gets of 64 KiB each; returns
/// (wall seconds, bytes written, a content digest).
fn drive(store: &SsdStorage, ops: usize) -> (f64, u64, u64) {
    const THREADS: usize = 8;
    const OBJ: usize = 64 << 10;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..THREADS {
            s.spawn(move || {
                let data: Vec<u8> = (0..OBJ).map(|j| (t * 131 + j * 7) as u8).collect();
                for i in 0..ops {
                    store.put(&format!("t{t}_k{i}"), &data).unwrap();
                }
                let mut out = Vec::new();
                for i in 0..ops {
                    store.get(&format!("t{t}_k{i}"), &mut out).unwrap();
                    assert_eq!(out.len(), OBJ);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let mut digest = 0u64;
    let mut out = Vec::new();
    for t in 0..THREADS {
        for i in 0..ops {
            store.get(&format!("t{t}_k{i}"), &mut out).unwrap();
            for (j, &b) in out.iter().enumerate() {
                digest = digest
                    .wrapping_mul(1099511628211)
                    .wrapping_add(b as u64 ^ (t * ops + i + j) as u64);
            }
        }
    }
    (wall, store.bytes_written(), digest)
}

fn short(model: ModelCfg, n_layers: u64) -> ModelCfg {
    let mut m = model;
    m.n_layers = n_layers;
    m
}

/// A builtin machine squeezed down to `cpu_gib` GiB of host DRAM — the
/// memory-starved regime where knob choices actually move the roofline.
fn tight(base: Machine, cpu_gib: u64) -> HwProfile {
    let mut m = base;
    m.cpu_mem = cpu_gib * GIB;
    HwProfile::builtin(m)
}

fn main() {
    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    let dev = bench_device();

    // ---- device curve sweep ----------------------------------------------
    let sized = DeviceProfile {
        read_bps: 3.2e9,
        write_bps: 2.8e9,
        qd_knee: 8,
        sat_bytes: 256 << 10,
        mix_penalty: 0.1,
        op_latency_s: 60e-6,
    };
    let mut curve: BTreeMap<String, Json> = BTreeMap::new();
    for qd in [1usize, 2, 4, 8, 16, 32] {
        for kib in [4u64, 16, 64, 256, 1024] {
            let bps = sized.eff_bps(false, kib << 10, qd, 1);
            curve.insert(format!("qd{qd}_kib{kib}"), Json::Num(bps));
        }
    }
    // sanity: the ramps are monotone where they should be
    assert!(
        sized.eff_bps(false, 4 << 10, 1, 1) < sized.eff_bps(false, 1 << 20, 8, 1),
        "small shallow requests must be priced below large deep ones"
    );
    report.insert("device_curve_bps".to_string(), Json::Obj(curve));

    // ---- real batching measurement (the >= 1.5x acceptance bar) -----------
    let ops = 40usize;
    let base = std::env::temp_dir().join(format!("gs_f19_{}", std::process::id()));
    std::fs::create_dir_all(&base).expect("create bench scratch dir");
    let unbatched = SsdStorage::with_profile(base.join("unbatched"), dev, None).unwrap();
    let batched = SsdStorage::with_profile(
        base.join("batched"),
        dev,
        Some(BatchConfig { max_bytes: 1 << 20, max_ops: 32 }),
    )
    .unwrap();
    let (t_un, b_un, d_un) = drive(&unbatched, ops);
    let (t_ba, b_ba, d_ba) = drive(&batched, ops);
    assert_eq!(b_un, b_ba, "batching must not change what is written");
    assert_eq!(d_un, d_ba, "batching must not change stored contents");
    let speedup = t_un / t_ba;
    assert!(
        speedup >= 1.5,
        "io-batch small-object speedup {speedup:.2}x is below the 1.5x bar \
         (unbatched {t_un:.3}s vs batched {t_ba:.3}s)"
    );
    let mut t = Table::new(
        "Fig. 19a — 64 KiB objects, 8 submitters, 200us latency floor",
        &["mode", "wall (s)", "MB/s", "speedup"],
    );
    let mb = (2.0 * b_un as f64) / 1e6; // the timed window moves puts + equal gets
    for (name, wall) in [("unbatched", t_un), ("io-batch 1MiB:32", t_ba)] {
        t.row(&[
            name.to_string(),
            format!("{wall:.3}"),
            format!("{:.0}", mb / wall),
            format!("{:.2}x", t_un / wall),
        ]);
    }
    t.emit(Some("bench_out/fig19_autotune.tsv"));
    let mut o = BTreeMap::new();
    o.insert("unbatched_wall_s".to_string(), Json::Num(t_un));
    o.insert("batched_wall_s".to_string(), Json::Num(t_ba));
    o.insert("speedup".to_string(), Json::Num(speedup));
    o.insert("object_kib".to_string(), Json::Num(64.0));
    o.insert("threads".to_string(), Json::Num(8.0));
    report.insert("batching".to_string(), Json::Obj(o));
    println!("io-batch small-object speedup: {speedup:.2}x (bar: 1.5x)");

    // ---- autotune vs hand-picked defaults (sim) ---------------------------
    let pairs: [(&str, HwProfile, ModelCfg); 2] = [
        ("a5000-16g/gpt65b-8L", tight(MACHINE1_A5000, 16), short(GPT_65B, 8)),
        ("a100-8g/gpt30b-8L", tight(MACHINE2_A100, 8), short(GPT_30B, 8)),
    ];
    let mut t = Table::new(
        "Fig. 19b — autotune vs hand-picked defaults (sim)",
        &["pair", "default tok/s", "tuned tok/s", "speedup", "roofline %"],
    );
    let mut tune_obj: BTreeMap<String, Json> = BTreeMap::new();
    for (name, hw, model) in pairs {
        let def = default_knobs(&hw, model, 2);
        let def_r = eval_knobs(&hw, model, 2, &def);
        let tuned = autotune(&hw, model, 2).unwrap();
        assert!(
            tuned.tokens_per_s > def_r.tokens_per_s,
            "{name}: tuned {:.0} tok/s must strictly beat default {:.0}",
            tuned.tokens_per_s,
            def_r.tokens_per_s
        );
        t.row(&[
            name.to_string(),
            format!("{:.0}", def_r.tokens_per_s),
            format!("{:.0}", tuned.tokens_per_s),
            format!("{:.2}x", tuned.tokens_per_s / def_r.tokens_per_s),
            format!("{:.0}%", 100.0 * tuned.roofline_frac()),
        ]);
        let mut o = BTreeMap::new();
        o.insert("default_tokens_per_s".to_string(), Json::Num(def_r.tokens_per_s));
        o.insert("tuned_tokens_per_s".to_string(), Json::Num(tuned.tokens_per_s));
        o.insert("roofline_frac".to_string(), Json::Num(tuned.roofline_frac()));
        o.insert("flags".to_string(), Json::Str(tuned.cli_flags()));
        tune_obj.insert(name.to_string(), Json::Obj(o));
    }
    t.emit(None);
    report.insert("autotune".to_string(), Json::Obj(tune_obj));

    std::fs::create_dir_all("bench_out").expect("create bench_out");
    let path = "bench_out/fig19_autotune.json";
    std::fs::write(path, Json::Obj(report).to_string_compact()).expect("write autotune report");
    println!("autotune report -> {path}");
    let _ = std::fs::remove_dir_all(&base);
}
