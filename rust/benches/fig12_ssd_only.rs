//! Figure 12 — 100 % SSD offloading vs the LP-optimal configuration
//! (GPT-65B, 1×A100). The SSD-only curve climbs more slowly but reaches a
//! similar saturated throughput — the evidence that vertical scheduling
//! itself, not CPU caching, drives the win (§6.4). The footer prints the
//! per-micro-batch time-credit arithmetic (paper: 16.4 s compute vs 1.1 s
//! checkpoint I/O).

use greedysnake::lp;
use greedysnake::machine::MACHINE2_A100;
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::{StorageRatios, SystemParams};
use greedysnake::sim::{simulate, Schedule};
use greedysnake::util::table::Table;

fn main() {
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    let mut t = Table::new(
        "Fig. 12 — GPT-65B 1×A100: optimal config vs 100% SSD offload (tokens/s)",
        &["global batch", "optimal config", "100% SSD"],
    );
    let mut last = (0.0, 0.0);
    for m in [2u64, 4, 8, 16, 32, 48, 64, 96, 128, 192, 256] {
        let best = lp::solve_config(&sp, m, 0.3)
            .map(|r| r.ratios)
            .unwrap_or(StorageRatios::ALL_SSD);
        let opt = simulate(&sp, m, Schedule::GreedySnake { alpha: 0.3, x: best });
        let ssd = simulate(
            &sp,
            m,
            Schedule::GreedySnake { alpha: 0.3, x: StorageRatios::ALL_SSD },
        );
        t.row(&[
            (m * 2).to_string(),
            format!("{:.0}", opt.tokens_per_s),
            format!("{:.0}", ssd.tokens_per_s),
        ]);
        last = (opt.tokens_per_s, ssd.tokens_per_s);
    }
    t.emit(Some("bench_out/fig12_ssd_only.tsv"));
    println!(
        "saturated: optimal {:.0} vs SSD-only {:.0} tokens/s ({:.0}% — paper: similar)",
        last.0,
        last.1,
        100.0 * last.1 / last.0
    );

    // §6.4 time credit
    let n = GPT_65B.n_layers as f64;
    let compute = n * (sp.t_fwd_mb() + sp.t_bwd_mb());
    let io = n * 5.0 * sp.c_bytes() / 24.0e9; // PCIe-staged checkpoints
    println!(
        "time credit per extra micro-batch: {compute:.1}s compute vs {io:.1}s ckpt I/O (paper: 16.4s vs 1.1s)"
    );
}
