//! Figure 10 — end-to-end throughput of all four systems vs global batch
//! size, for every paper evaluation panel, on the discrete-event simulator
//! (substituted testbed; DESIGN.md). Also prints the §6.2 saturated-speedup
//! summary and TFLOPs/GPU.

use std::collections::BTreeMap;

use greedysnake::lp;
use greedysnake::machine::{Machine, MACHINE1_A5000, MACHINE2_A100};
use greedysnake::modelcfg::{ModelCfg, GPT_175B, GPT_30B, GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::{StorageRatios, SystemParams};
use greedysnake::sim::{simulate, Schedule, SimResult};
use greedysnake::util::json::Json;
use greedysnake::util::table::Table;

struct Panel {
    model: ModelCfg,
    machine: Machine,
    gpus: u64,
    /// micro-batch counts to sweep (per GPU)
    ms: &'static [u64],
}

fn main() {
    let panels = [
        Panel { model: GPT_30B, machine: MACHINE1_A5000, gpus: 1, ms: &[2, 4, 8, 16, 32, 48] },
        Panel { model: GPT_30B, machine: MACHINE1_A5000, gpus: 4, ms: &[2, 4, 8, 16, 32] },
        Panel { model: GPT_65B, machine: MACHINE1_A5000, gpus: 1, ms: &[2, 4, 8, 16, 32, 48] },
        Panel { model: GPT_65B, machine: MACHINE2_A100, gpus: 1, ms: &[2, 4, 8, 16, 32, 48, 64] },
        Panel { model: GPT_65B, machine: MACHINE2_A100, gpus: 4, ms: &[2, 4, 8, 16, 32, 48] },
        Panel { model: GPT_175B, machine: MACHINE2_A100, gpus: 1, ms: &[2, 4, 8, 16, 32, 48, 64] },
    ];

    let mut speedups = Vec::new();
    let mut tflops_summary = Vec::new();
    // Per-panel, per-schedule pipeline-stall accounting (GPU-idle seconds
    // per iteration at the panel's largest batch) — machine-readable so
    // future PRs can track the overlap win.
    let mut stall_report: BTreeMap<String, Json> = BTreeMap::new();

    for p in &panels {
        // GreedySnake runs at its LP-preferred small micro-batch (B=2);
        // ZeRO-Infinity/TeraIO get their most favorable LARGE micro-batch
        // (B=8, like the paper's §6.2 methodology) at the same global batch.
        let sp = SystemParams::new(p.machine.with_gpus(p.gpus), p.model, 2, SEQ_LEN);
        let b_z = 8u64;
        let sp_z = SystemParams::new(p.machine.with_gpus(p.gpus), p.model, b_z, SEQ_LEN);
        let title = format!(
            "Fig. 10 — {} on {} x{} (tokens/s vs global batch)",
            p.model.name, p.machine.name, p.gpus
        );
        // column labels double as runtime schedule names (Schedule::kind_name
        // / trainer::ScheduleKind grammar) where one exists
        let chunk_group = 4u64;
        let chunk_label = format!(
            "GS {}",
            Schedule::ChunkedVertical { group: chunk_group, x: StorageRatios::ALL_SSD }
                .kind_name()
        );
        let mut t = Table::new(
            &title,
            &[
                "global batch",
                "ZeRO-Infinity",
                "Ratel",
                "TeraIO",
                &chunk_label,
                "GreedySnake",
                "perf model",
            ],
        );

        // Ratel runs once at its max single-pass batch.
        let ratel = simulate(&sp, 1, Schedule::Ratel);
        let ratel_batch = sp.single_pass_max_batch(true) * p.gpus;

        let mut best_v: f64 = 0.0;
        let mut best_z: f64 = 0.0;
        let mut best_v_tflops = 0.0;
        for &m in p.ms {
            // same global batch: m·2 for GreedySnake = m_z·8 for ZeRO
            let m_z = (m * 2 / b_z).max(1);
            let z = simulate(&sp_z, m_z, Schedule::ZeroInfinity);
            let teraio = simulate(&sp_z, m_z, Schedule::TeraIo);
            let (alpha, x) = match lp_best(&sp, m) {
                Some((a, x)) => (a, x),
                None => (0.0, StorageRatios::ALL_SSD),
            };
            let v = simulate(&sp, m, Schedule::GreedySnake { alpha, x });
            // chunked-vertical ablation: same placement, G micro-batches
            // per vertical sweep (between the two traversal extremes)
            let ch = simulate(&sp, m, Schedule::ChunkedVertical { group: chunk_group, x });
            let pm = lp::solve_config(&sp, m, alpha)
                .map(|r| r.tokens_per_s)
                .unwrap_or(f64::NAN);
            if v.tokens_per_s > best_v {
                best_v = v.tokens_per_s;
                best_v_tflops = v.tflops_per_gpu;
            }
            best_z = best_z.max(z.tokens_per_s);
            let ratel_cell = if m * 2 * p.gpus >= ratel_batch && m == p.ms[p.ms.len() - 1] {
                format!("{:.0} (b={ratel_batch})", ratel.tokens_per_s)
            } else if m == p.ms[0] {
                format!("{:.0} (b={ratel_batch})", ratel.tokens_per_s)
            } else {
                "-".into()
            };
            t.row(&[
                (m * 2 * p.gpus).to_string(),
                format!("{:.0}", z.tokens_per_s),
                ratel_cell,
                format!("{:.0}", teraio.tokens_per_s),
                format!("{:.0}", ch.tokens_per_s),
                format!("{:.0}", v.tokens_per_s),
                format!("{:.0}", pm),
            ]);
            if m == p.ms[p.ms.len() - 1] {
                let panel_key = format!(
                    "{}_{}x{}",
                    p.model.name.to_lowercase(),
                    p.machine.name.to_lowercase(),
                    p.gpus
                );
                let mut schedules = BTreeMap::new();
                for (name, res) in [
                    ("zero-infinity", &z),
                    ("teraio", &teraio),
                    (chunk_label.as_str(), &ch),
                    ("greedysnake", &v),
                ] {
                    schedules.insert(name.to_string(), stall_json(res));
                }
                stall_report.insert(panel_key, Json::Obj(schedules));
            }
        }
        let tsv = format!(
            "bench_out/fig10_{}_{}x{}.tsv",
            p.model.name.to_lowercase(),
            p.machine.name.to_lowercase(),
            p.gpus
        );
        t.emit(Some(&tsv));
        let sp_up = best_v / best_z;
        println!("saturated speedup over ZeRO-Infinity: {sp_up:.2}x\n");
        speedups.push((title, sp_up));
        tflops_summary.push((p.model.name, p.machine.name, p.gpus, best_v_tflops));
    }

    println!("=== §6.2 summary (paper: 1.96x 65B/1GPU, 1.93x 65B/4GPU, 2.53x 175B/1GPU on A100) ===");
    for (title, s) in &speedups {
        println!("  {s:.2}x  {title}");
    }
    println!("\n=== TFLOPs/GPU at saturation (paper: 63.1 A5000-65B/4GPU, 128.3 A100-175B-ish) ===");
    for (model, machine, gpus, tf) in &tflops_summary {
        println!("  {model} on {machine} x{gpus}: {tf:.1} TFLOPs/GPU");
    }

    std::fs::create_dir_all("bench_out").expect("create bench_out");
    let path = "bench_out/fig10_stalls.json";
    std::fs::write(path, Json::Obj(stall_report).to_string_compact())
        .expect("write stall report");
    println!("\nper-schedule stall-time report -> {path}");
}

/// GPU-idle ("stall") seconds per steady-state iteration for one simulated
/// schedule, plus the raw inputs.
fn stall_json(r: &SimResult) -> Json {
    let mut o = BTreeMap::new();
    o.insert("t_iter_s".to_string(), Json::Num(r.t_iter));
    o.insert("gpu_util".to_string(), Json::Num(r.gpu_util));
    o.insert("stall_s".to_string(), Json::Num(r.t_iter * (1.0 - r.gpu_util)));
    o.insert("tokens_per_s".to_string(), Json::Num(r.tokens_per_s));
    Json::Obj(o)
}

fn lp_best(sp: &SystemParams, m: u64) -> Option<(f64, StorageRatios)> {
    let mut best: Option<(f64, StorageRatios, f64)> = None;
    for i in (0..=50).step_by(5) {
        let a = i as f64 / 100.0;
        if let Some(r) = lp::solve_config(sp, m, a.max(0.01)) {
            if best.is_none_or(|(_, _, t)| r.tokens_per_s > t) {
                best = Some((r.alpha, r.ratios, r.tokens_per_s));
            }
        }
    }
    best.map(|(a, x, _)| (a, x))
}
