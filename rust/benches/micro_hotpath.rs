//! Micro-benchmarks of the Layer-3 hot paths (the §Perf targets): fused
//! Rust Adam, the AOT Pallas Adam kernel, PJRT stage dispatch, the
//! SSD tier, the multi-path transfer planner (plan construction +
//! extent-split dispatch), the lane executor, and the LP solve. Drives the
//! EXPERIMENTS.md §Perf before/after log.

use greedysnake::machine::MACHINE2_A100;
use greedysnake::memory::{
    plan_shares, BatchConfig, DeviceProfile, PlannedConfig, PlannedStore, SsdStorage,
};
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::optimizer::{adam_step_hlo, adam_step_rust, AdamParams, AdamState};
use greedysnake::perfmodel::SystemParams;
use greedysnake::runtime::tensor::HostTensor;
use greedysnake::runtime::{Manifest, Runtime, Stage};
use greedysnake::sim::{simulate, Schedule};
use greedysnake::util::bench::{black_box, Bench};
use greedysnake::util::prng::Prng;

fn main() -> anyhow::Result<()> {
    // --- multi-path transfer planner (no artifacts needed) ------------------
    // plan construction alone (the per-object share split), then the full
    // split→parallel-dispatch→reassemble round trip on an unthrottled
    // 4-path store vs the flat single-device baseline — the delta IS the
    // planner's extent-split + thread-fanout overhead.
    let mut b0 = Bench::new("planner").warmup(2).iters(10);
    let weights = [8000u64, 3200, 3200, 200]; // DRAM + 2 NVMe + remote
    b0.run("plan_shares_4path_8MB", || black_box(plan_shares(8 << 20, &weights)));
    let planned = PlannedStore::create(
        std::env::temp_dir().join(format!("gs_bench_plan_{}", std::process::id())),
        &PlannedConfig {
            nvme: vec![(f64::INFINITY, f64::INFINITY); 2],
            dram_capacity: 64 << 20,
            dram_bps: f64::INFINITY,
            remote_bps: f64::INFINITY,
        },
    )?;
    let flat = SsdStorage::create_unthrottled(
        std::env::temp_dir().join(format!("gs_bench_flat_{}", std::process::id())),
    )?;
    let blob: Vec<u8> = vec![7u8; 4 << 20];
    let mut raw = Vec::new();
    b0.run("planned_put_get_4MB", || {
        planned.put("pk", &blob).unwrap();
        planned.get("pk", &mut raw).unwrap();
        black_box(raw.len())
    });
    b0.run("flat_put_get_4MB", || {
        flat.put("pk", &blob).unwrap();
        flat.get("pk", &mut raw).unwrap();
        black_box(raw.len())
    });

    // --- NVMe device model (no artifacts needed) ----------------------------
    // the per-submit pricing cost (eff_bps runs on every throttled transfer)
    // and the io_uring-style ring window on a latency-floored device: 4
    // concurrent submitters × 8 small puts, unbatched vs batched — the
    // delta IS the amortized latency floor.
    let mut b6 = Bench::new("nvme").warmup(1).iters(5);
    let curve = DeviceProfile {
        read_bps: 3.2e9,
        write_bps: 2.8e9,
        qd_knee: 8,
        sat_bytes: 256 << 10,
        mix_penalty: 0.1,
        op_latency_s: 60e-6,
    };
    b6.run("eff_bps_eval", || {
        let mut acc = 0.0f64;
        for qd in 1usize..=32 {
            acc += curve.eff_bps(qd % 2 == 0, (qd as u64) << 12, qd, 4);
        }
        black_box(acc)
    });
    let floor = DeviceProfile {
        read_bps: f64::INFINITY,
        write_bps: f64::INFINITY,
        qd_knee: 4,
        sat_bytes: 1 << 20,
        mix_penalty: 0.0,
        op_latency_s: 30e-6,
    };
    let small_put_burst = |store: &SsdStorage| {
        std::thread::scope(|s| {
            for t in 0..4 {
                s.spawn(move || {
                    let data = vec![t as u8; 16 << 10];
                    for i in 0..8 {
                        store.put(&format!("b_{t}_{i}"), &data).unwrap();
                    }
                });
            }
        });
    };
    let un = SsdStorage::with_profile(
        std::env::temp_dir().join(format!("gs_bench_nvme_un_{}", std::process::id())),
        floor,
        None,
    )?;
    let ba = SsdStorage::with_profile(
        std::env::temp_dir().join(format!("gs_bench_nvme_ba_{}", std::process::id())),
        floor,
        Some(BatchConfig::default()),
    )?;
    b6.run("small_put_burst_unbatched", || small_put_burst(&un));
    b6.run("small_put_burst_batched", || small_put_burst(&ba));

    let manifest = Manifest::load("artifacts/tiny")?;
    let rt = Runtime::load(&manifest)?;
    let mut rng = Prng::new(0);

    // --- CPU Adam: rust fused loop vs AOT Pallas kernel -------------------
    let n = 1 << 20;
    let mut p = vec![0.0f32; n];
    rng.fill_normal(&mut p, 1.0);
    let mut g = vec![0.0f32; n];
    rng.fill_normal(&mut g, 0.1);
    let hp = AdamParams::default();

    let mut b = Bench::new("adam").warmup(2).iters(8);
    let mut st = AdamState::zeros(n);
    b.run("rust_fused_1M", || {
        adam_step_rust(&mut p, &mut st, &g, &hp, 1, 1.0, 0, n);
        black_box(p[0])
    });
    let rust_mean = b.mean_of("rust_fused_1M").unwrap();
    println!(
        "  -> {:.2} Gelem/s ({:.1} GB/s of p/m/v/g state streamed)",
        n as f64 / rust_mean / 1e9,
        n as f64 * 28.0 / rust_mean / 1e9 // 4 streams in, 3 out, 4 B each
    );
    let mut st2 = AdamState::zeros(n);
    let chunk = manifest.config.adam_chunk;
    b.run("hlo_pallas_1M", || {
        adam_step_hlo(&rt, chunk, &mut p, &mut st2, &g, &hp, 1, 1.0, 0, n).unwrap();
        black_box(p[0])
    });

    // --- PJRT stage dispatch ----------------------------------------------
    let c = manifest.config;
    let mut x = HostTensor::zeros(&[c.micro_batch, c.seq_len, c.hidden]);
    rng.fill_normal(&mut x.data, 1.0);
    let params: Vec<HostTensor> = manifest
        .layer_params
        .iter()
        .map(|s| HostTensor::init(s, c.n_layers, &mut rng))
        .collect();
    let lits: Vec<xla::Literal> = params.iter().map(|p| p.to_literal().unwrap()).collect();
    let mut b2 = Bench::new("pjrt").warmup(3).iters(20);
    b2.run("layer_fwd_tiny", || {
        let mut inputs = vec![x.to_literal().unwrap()];
        inputs.extend(lits.iter().map(|l| l.clone()));
        black_box(rt.execute(Stage::LayerFwd, &inputs).unwrap())
    });
    b2.run("literal_upload_only", || {
        let mut inputs = Vec::with_capacity(13);
        inputs.push(x.to_literal().unwrap());
        inputs.extend(lits.iter().map(|l| l.clone()));
        black_box(inputs)
    });

    // --- SSD tier -----------------------------------------------------------
    let ssd = SsdStorage::create_unthrottled(
        std::env::temp_dir().join(format!("gs_bench_ssd_{}", std::process::id())),
    )?;
    let buf: Vec<f32> = vec![1.0; 1 << 20];
    let mut out = Vec::new();
    let mut b3 = Bench::new("ssd").warmup(2).iters(10);
    b3.run("put_get_4MB", || {
        ssd.put_f32("k", &buf).unwrap();
        ssd.get_f32("k", &mut out).unwrap();
        black_box(out.len())
    });
    // the get_f32 scratch-buffer fix: the old default decoded through a
    // fresh Vec each call; the trait default now stages through a reusable
    // thread-local. The replica below re-creates the allocate-per-call
    // behavior for the before/after delta.
    use greedysnake::memory::store::TensorStore;
    b3.run("get_f32_alloc_per_call", || {
        let mut raw: Vec<u8> = Vec::new();
        TensorStore::get(&ssd, "k", &mut raw).unwrap();
        out.clear();
        out.extend(raw.chunks_exact(4).map(|c| {
            f32::from_le_bytes([c[0], c[1], c[2], c[3]])
        }));
        black_box(out.len())
    });
    b3.run("get_f32_reuse_scratch", || {
        TensorStore::get_f32(&ssd, "k", &mut out).unwrap();
        black_box(out.len())
    });
    // the codec boundary on the same object (encode + decode per pass)
    let codec_store = greedysnake::memory::CodecStore::new(
        std::sync::Arc::new(SsdStorage::create_unthrottled(
            std::env::temp_dir().join(format!("gs_bench_codec_{}", std::process::id())),
        )?),
        greedysnake::memory::Precision::MixedF16.policy(),
    );
    b3.run("codec_f16_put_get_4MB", || {
        codec_store.put_f32("ilc_k", &buf).unwrap();
        codec_store.get_f32("ilc_k", &mut out).unwrap();
        black_box(out.len())
    });

    // --- lane executor dispatch overhead ------------------------------------
    let mut b4 = Bench::new("lanes").warmup(2).iters(10);
    b4.run("1000_dependent_ops", || {
        let mut ex = greedysnake::exec::LaneExecutor::new(&["a", "b"]);
        let mut prev = None;
        for i in 0..1000 {
            let deps: Vec<_> = prev.into_iter().collect();
            prev = Some(ex.submit(i % 2, &deps, || {}));
        }
        ex.wait_all();
    });

    // --- LP + simulator ------------------------------------------------------
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    let mut b5 = Bench::new("analytics").warmup(1).iters(5);
    b5.run("lp_solve", || black_box(greedysnake::lp::solve_config(&sp, 16, 0.25)));
    b5.run("sim_65b_m16", || {
        black_box(simulate(
            &sp,
            16,
            Schedule::GreedySnake {
                alpha: 0.3,
                x: greedysnake::perfmodel::StorageRatios::ALL_CPU,
            },
        ))
    });
    Ok(())
}
