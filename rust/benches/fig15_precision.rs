//! Fig. 15 (precision panel) — mixed-precision storage codecs under the
//! TensorStore: strict f32 vs `mixed:f16`/`mixed:bf16` end to end.
//!
//! * **simulated** (`sim::simulate_store_prec`): the per-category storage
//!   byte multipliers ([`greedysnake::perfmodel::ByteMults`]) applied to an
//!   SSD-bound placement across the schedule families — mixed precision
//!   must strictly undercut strict f32 wherever the storage tier binds;
//! * **closed forms** (`traffic::Workload::*_enc`): encoded per-iteration
//!   store bytes under each [`PrecisionPolicy`] — moments stay f32 under
//!   every policy, checkpoints halve EXACTLY under the mixed policies, and
//!   the fit-or-nothing cache law is evaluated per policy (a cache sized to
//!   the f16 working set absorbs mixed but not strict);
//! * **real runtime** (when the AOT artifacts are built): short runs with
//!   the store carrying only checkpoints (`--opt-on-ssd false`), where the
//!   measured `ssd_read`/`ssd_written`/`param_bytes` under `mixed:f16` must
//!   be ≤ 0.55× strict f32 (exactly 0.5× by construction) and losses must
//!   track the strict run within tolerance.
//!
//! Emits `bench_out/fig15_precision.json` (uploaded as a CI artifact) plus
//! a human-readable table.

use std::collections::BTreeMap;

use greedysnake::coordinator::TrainerConfig;
use greedysnake::machine::MACHINE2_A100;
use greedysnake::memory::Precision;
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::{ByteMults, StorageRatios, SystemParams};
use greedysnake::sim::{simulate_store_prec, Schedule};
use greedysnake::traffic::Workload;
use greedysnake::trainer::{train, RunLog, ScheduleKind};
use greedysnake::util::json::Json;
use greedysnake::util::table::Table;

fn main() {
    let m = 16u64;
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    let x = StorageRatios::ALL_SSD; // the storage tier IS the bottleneck
    let wl = Workload { model: GPT_65B, micro_batch: 2, seq_len: SEQ_LEN, m, shards: 1 };

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("model".to_string(), Json::Str("gpt-65b".to_string()));
    report.insert("machine".to_string(), Json::Str("a100".to_string()));
    report.insert("m".to_string(), Json::Num(m as f64));

    // ---- sim sweep: schedules × precision ---------------------------------
    let precisions = [Precision::F32, Precision::MixedF16, Precision::MixedBf16];
    let scheds = [
        Schedule::GreedySnake { alpha: 0.0, x },
        Schedule::ZeroInfinity,
        Schedule::TeraIo,
    ];
    let mut t = Table::new(
        "Fig. 15 (precision) — GPT-65B A100, all-SSD placement",
        &["schedule", "precision", "t_iter (s)", "speedup vs f32"],
    );
    let mut sim_obj: BTreeMap<String, Json> = BTreeMap::new();
    for sched in scheds {
        let strict = simulate_store_prec(
            &sp,
            m,
            sched,
            usize::MAX,
            1,
            0,
            ByteMults::for_precision(Precision::F32),
        );
        for p in precisions {
            let r = simulate_store_prec(
                &sp,
                m,
                sched,
                usize::MAX,
                1,
                0,
                ByteMults::for_precision(p),
            );
            assert!(
                r.t_iter <= strict.t_iter,
                "{}/{p}: mixed sim {} must not exceed strict {}",
                sched.kind_name(),
                r.t_iter,
                strict.t_iter
            );
            t.row(&[
                sched.kind_name(),
                format!("{p}"),
                format!("{:.2}", r.t_iter),
                format!("{:.2}x", strict.t_iter / r.t_iter),
            ]);
            let mut o = BTreeMap::new();
            o.insert("t_iter_s".to_string(), Json::Num(r.t_iter));
            o.insert("tokens_per_s".to_string(), Json::Num(r.tokens_per_s));
            o.insert(
                "speedup_vs_f32".to_string(),
                Json::Num(strict.t_iter / r.t_iter),
            );
            sim_obj.insert(format!("{}/{p}", sched.kind_name()), Json::Obj(o));
        }
    }
    // the SSD-bound GreedySnake leg must see a STRICT win from halving
    let gs_strict = simulate_store_prec(
        &sp,
        m,
        scheds[0],
        usize::MAX,
        1,
        0,
        ByteMults::for_precision(Precision::F32),
    );
    let gs_mixed = simulate_store_prec(
        &sp,
        m,
        scheds[0],
        usize::MAX,
        1,
        0,
        ByteMults::for_precision(Precision::MixedF16),
    );
    assert!(
        gs_mixed.t_iter < gs_strict.t_iter,
        "all-SSD GreedySnake: mixed sim {} must beat strict {}",
        gs_mixed.t_iter,
        gs_strict.t_iter
    );
    t.emit(Some("bench_out/fig15_precision.tsv"));
    report.insert("sim".to_string(), Json::Obj(sim_obj));

    // ---- closed forms: encoded store bytes per policy ---------------------
    let mut forms: BTreeMap<String, Json> = BTreeMap::new();
    let strict_pol = Precision::F32.policy();
    for p in precisions {
        let pol = p.policy();
        let mut o = BTreeMap::new();
        o.insert(
            "moment_bytes".to_string(),
            Json::Num(wl.runtime_moment_bytes_enc(&pol) as f64),
        );
        o.insert(
            "store_read_bytes".to_string(),
            Json::Num(wl.store_read_bytes_enc(true, true, &pol) as f64),
        );
        o.insert(
            "working_set_bytes".to_string(),
            Json::Num(wl.store_working_set_bytes_enc(true, true, &pol) as f64),
        );
        forms.insert(format!("{p}"), Json::Obj(o));
        // Adam moments stay f32 under EVERY policy …
        assert_eq!(
            wl.runtime_moment_bytes_enc(&pol),
            wl.runtime_moment_bytes_enc(&strict_pol)
        );
        // … and the checkpoint stream halves EXACTLY under the mixed ones.
        if !pol.is_strict_f32() {
            assert_eq!(
                2 * wl.store_read_bytes_enc(false, true, &pol),
                wl.store_read_bytes_enc(false, true, &strict_pol),
                "{p}: encoded checkpoint bytes must be exactly half of strict f32"
            );
        }
    }
    // fit-or-nothing per policy: a cache sized to the f16 working set
    // absorbs the mixed run but overflows on its strict f32 twin.
    let f16_pol = Precision::MixedF16.policy();
    let f16_ws = wl.store_working_set_bytes_enc(true, true, &f16_pol);
    assert_eq!(wl.cached_store_read_bytes_enc(true, true, &f16_pol, f16_ws), 0);
    assert_eq!(
        wl.cached_store_read_bytes_enc(true, true, &strict_pol, f16_ws),
        wl.store_read_bytes_enc(true, true, &strict_pol)
    );
    forms.insert("f16_working_set_bytes".to_string(), Json::Num(f16_ws as f64));
    report.insert("closed_forms".to_string(), Json::Obj(forms));
    println!(
        "closed forms: f16 working set {} vs strict {}",
        greedysnake::util::stats::fmt_bytes(f16_ws as f64),
        greedysnake::util::stats::fmt_bytes(
            wl.store_working_set_bytes_enc(true, true, &strict_pol) as f64
        ),
    );

    // ---- real-runtime leg (skips without AOT artifacts) -------------------
    let runtime_status = match greedysnake::runtime::test_artifacts("artifacts/tiny") {
        None => {
            println!("runtime precision leg: skipped (artifacts/tiny not built)");
            "skipped".to_string()
        }
        Some(_) => {
            // store carries ONLY checkpoints so the byte ratio is pure
            // codec arithmetic: 2 B/elem vs 4 B/elem = exactly 0.5×.
            let mk = |tag: &str, precision: Precision| TrainerConfig {
                alpha: 0.0,
                opt_on_ssd: false,
                ckpt_on_ssd: true,
                overlap: false,
                io_depth: 0,
                precision,
                ssd_path: std::env::temp_dir()
                    .join(format!("gs_f15_{tag}_{}", std::process::id())),
                ..Default::default()
            };
            let manifest = || greedysnake::runtime::Manifest::load("artifacts/tiny").unwrap();
            let go = |tag: &str, precision: Precision| -> RunLog {
                train(manifest(), mk(tag, precision), ScheduleKind::Vertical, 3, 3, 0)
                    .unwrap()
            };
            let strict = go("f32", Precision::F32);
            let mixed = go("f16", Precision::MixedF16);
            assert!(strict.ssd_read > 0 && strict.ssd_written > 0);
            let traffic = |log: &RunLog| log.ssd_read + log.ssd_written + log.param_bytes;
            let ratio = traffic(&mixed) as f64 / traffic(&strict) as f64;
            assert!(
                ratio <= 0.55,
                "mixed:f16 param+checkpoint traffic ratio {ratio:.3} must be <= 0.55"
            );
            // and with a checkpoint-only store the halving is EXACT
            assert_eq!(2 * mixed.ssd_read, strict.ssd_read);
            assert_eq!(2 * mixed.ssd_written, strict.ssd_written);
            let max_dev = strict
                .losses
                .iter()
                .zip(&mixed.losses)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(
                max_dev < 0.1,
                "mixed:f16 losses must track strict f32 (max dev {max_dev:.3e})"
            );
            let mut o = BTreeMap::new();
            o.insert(
                "strict_store_bytes".to_string(),
                Json::Num((strict.ssd_read + strict.ssd_written) as f64),
            );
            o.insert(
                "mixed_store_bytes".to_string(),
                Json::Num((mixed.ssd_read + mixed.ssd_written) as f64),
            );
            o.insert("traffic_ratio".to_string(), Json::Num(ratio));
            o.insert("max_loss_dev".to_string(), Json::Num(max_dev));
            report.insert("runtime".to_string(), Json::Obj(o));
            println!(
                "runtime precision leg: mixed:f16 traffic ratio {ratio:.3} \
                 (bound 0.55), max loss dev {max_dev:.3e}",
            );
            "ok".to_string()
        }
    };
    report.insert("runtime_status".to_string(), Json::Str(runtime_status));

    std::fs::create_dir_all("bench_out").expect("create bench_out");
    let path = "bench_out/fig15_precision.json";
    std::fs::write(path, Json::Obj(report).to_string_compact())
        .expect("write precision report");
    println!("precision report -> {path}");
}
