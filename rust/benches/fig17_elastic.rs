//! Fig. 17 (elastic sharding panel) — persistence-sharded master parameters
//! and crash-consistent recovery at W ∈ {1, 2, 4, 8}:
//!
//! * **closed forms** (`traffic::Workload`): per-rank parameter SSD round
//!   trips under `--param-persist` — the acceptance property is that they
//!   scale ~1/W (each rank re-reads and re-writes only its own shard) while
//!   the host-resident path round-trips nothing;
//! * **simulated** (GPT-65B on the A100 node, `sim::simulate_dist`): the
//!   iteration-time cost of the per-rank parameter round trip, plus a
//!   recovery-overhead sweep — a worker lost every MTBF steps replays one
//!   step from the last committed epoch boundary, so the expected slowdown
//!   is `t_iter / MTBF` per step;
//! * **real runtime** (when the AOT artifacts are built): a short
//!   `--param-persist --journal --workers 2` run with an injected
//!   mid-step fault must recover and end bit-identical to the plain
//!   `--workers 1` baseline, with per-rank shard counters carrying ~1/W
//!   of the byte total each.
//!
//! Emits `bench_out/fig17_elastic.json` (uploaded as a CI artifact) plus a
//! human-readable table.

use std::collections::BTreeMap;

use greedysnake::coordinator::TrainerConfig;
use greedysnake::lp;
use greedysnake::machine::MACHINE2_A100;
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::{StorageRatios, SystemParams};
use greedysnake::sim::{simulate_dist, DistConfig, Schedule};
use greedysnake::traffic::Workload;
use greedysnake::trainer::{train, ScheduleKind};
use greedysnake::util::json::Json;
use greedysnake::util::table::Table;

fn main() {
    let m = 32u64;
    let alpha = 0.3;
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    let x = lp::solve_config(&sp, m, alpha)
        .map(|r| r.ratios)
        .unwrap_or(StorageRatios::ALL_SSD);
    let sched = Schedule::GreedySnake { alpha, x };
    let wl = Workload { model: GPT_65B, micro_batch: 2, seq_len: SEQ_LEN, m, shards: 1 };

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("model".to_string(), Json::Str("gpt-65b".to_string()));
    report.insert("machine".to_string(), Json::Str("a100".to_string()));
    report.insert("schedule".to_string(), Json::Str(sched.kind_name()));
    report.insert("m_global".to_string(), Json::Num(m as f64));
    report.insert("alpha".to_string(), Json::Num(alpha));

    let mut t = Table::new(
        "Fig. 17 (elastic sharding) — GPT-65B A100, persistence-sharded parameters",
        &[
            "W",
            "resident tok/s",
            "persist tok/s",
            "cost",
            "param SSD/rank",
            "ovh @MTBF=100",
            "ovh @MTBF=1000",
        ],
    );
    let mut per_w: BTreeMap<String, Json> = BTreeMap::new();
    let full_rt = wl.param_ssd_round_trip_bytes();
    for w in [1usize, 2, 4, 8] {
        let base = DistConfig { workers: w, ssds: 1, ..DistConfig::default() };
        let resident = simulate_dist(&sp, m, sched, base);
        let persist =
            simulate_dist(&sp, m, sched, DistConfig { param_persist: true, ..base });
        let cost = persist.t_iter / resident.t_iter;
        let per_rank = wl.sharded_param_ssd_bytes_per_rank(w as u64);
        // the acceptance property: per-rank parameter SSD bytes ~1/W
        assert!(
            per_rank <= full_rt / w as u64 + w as u64,
            "W={w}: per-rank param bytes {per_rank} not ~1/W of {full_rt}"
        );
        // recovery overhead: one lost worker per MTBF steps replays one
        // step from the last epoch boundary — expected t_iter/MTBF per step
        let ovh = |mtbf: f64| 100.0 / mtbf;
        t.row(&[
            w.to_string(),
            format!("{:.0}", resident.tokens_per_s),
            format!("{:.0}", persist.tokens_per_s),
            format!("{cost:.3}x"),
            greedysnake::util::stats::fmt_bytes(per_rank as f64),
            format!("{:.2}%", ovh(100.0)),
            format!("{:.3}%", ovh(1000.0)),
        ]);
        let mut o = BTreeMap::new();
        o.insert("resident_t_iter_s".to_string(), Json::Num(resident.t_iter));
        o.insert("persist_t_iter_s".to_string(), Json::Num(persist.t_iter));
        o.insert("resident_tokens_per_s".to_string(), Json::Num(resident.tokens_per_s));
        o.insert("persist_tokens_per_s".to_string(), Json::Num(persist.tokens_per_s));
        o.insert("persist_cost_vs_resident".to_string(), Json::Num(cost));
        o.insert("param_ssd_bytes_per_rank".to_string(), Json::Num(per_rank as f64));
        o.insert("param_ssd_round_trip_total".to_string(), Json::Num(full_rt as f64));
        let mut rec = BTreeMap::new();
        for mtbf in [100u64, 1000, 10000] {
            rec.insert(mtbf.to_string(), Json::Num(persist.t_iter / mtbf as f64));
        }
        o.insert("recovery_overhead_s_per_step_by_mtbf".to_string(), Json::Obj(rec));
        per_w.insert(w.to_string(), Json::Obj(o));
    }
    t.emit(Some("bench_out/fig17_elastic.tsv"));
    report.insert("workers".to_string(), Json::Obj(per_w));
    println!(
        "per-rank parameter SSD round trip: {} at W=1 -> {} at W=8 (~1/W)",
        greedysnake::util::stats::fmt_bytes(full_rt as f64),
        greedysnake::util::stats::fmt_bytes(wl.sharded_param_ssd_bytes_per_rank(8) as f64),
    );

    // ---- real-runtime recovery leg (skips without AOT artifacts) ---------
    let runtime_status = match greedysnake::runtime::test_artifacts("artifacts/tiny") {
        None => {
            println!("runtime recovery: skipped (artifacts/tiny not built)");
            "skipped".to_string()
        }
        Some(_) => {
            let mk = |tag: &str, workers: usize, persist: bool| TrainerConfig {
                opt_on_ssd: persist,
                param_persist: persist,
                journal: persist,
                workers,
                shard_optimizer: workers > 1,
                ssd_path: std::env::temp_dir()
                    .join(format!("gs_f17el_{tag}_{}", std::process::id())),
                ..Default::default()
            };
            let manifest = || greedysnake::runtime::Manifest::load("artifacts/tiny").unwrap();
            let base =
                train(manifest(), mk("w1", 1, false), ScheduleKind::Vertical, 6, 4, 0).unwrap();
            // a worker lost at the start of step 2 (the delayed-dispatch
            // site is hit once per step); the journal must replay it
            greedysnake::util::fault::arm("opt:delayed", 2);
            let recovered =
                train(manifest(), mk("w2j", 2, true), ScheduleKind::Vertical, 6, 4, 0).unwrap();
            assert_eq!(recovered.recoveries, 1, "the injected fault never fired");
            assert_eq!(base.losses, recovered.losses, "replayed losses diverged");
            assert_eq!(
                base.param_sq_norm.to_bits(),
                recovered.param_sq_norm.to_bits(),
                "recovered parameters diverged"
            );
            assert_eq!(
                base.moment_sq_norm.to_bits(),
                recovered.moment_sq_norm.to_bits(),
                "recovered optimizer moments diverged"
            );
            let rd = &recovered.param_shard_reads;
            assert_eq!(rd.len(), 2, "one shard counter per rank");
            println!(
                "runtime recovery: W=2 journaled run replayed 1 fault bit-identically \
                 (shard reads {} / {})",
                greedysnake::util::stats::fmt_bytes(rd[0] as f64),
                greedysnake::util::stats::fmt_bytes(rd[1] as f64),
            );
            "ok".to_string()
        }
    };
    report.insert("runtime_recovery".to_string(), Json::Str(runtime_status));

    std::fs::create_dir_all("bench_out").expect("create bench_out");
    let path = "bench_out/fig17_elastic.json";
    std::fs::write(path, Json::Obj(report).to_string_compact()).expect("write elastic report");
    println!("elastic report -> {path}");
}
