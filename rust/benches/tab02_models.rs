//! Table 2 — the model zoo, with the derived per-layer quantities every
//! analytic component depends on (params/layer, checkpoint size, optimizer
//! state footprint, and the §3.4 layer-to-checkpoint ratio).

use greedysnake::modelcfg::{SEQ_LEN, TABLE2};
use greedysnake::util::stats::fmt_bytes;
use greedysnake::util::table::Table;

fn main() {
    let mut t = Table::new(
        "Table 2 — evaluated models (derived quantities at T=2048, mb=8)",
        &[
            "model", "#layers", "#heads", "hidden", "total params",
            "params/layer", "opt state", "ckpt/mb/layer", "layer/ckpt ratio",
        ],
    );
    for m in TABLE2 {
        let ckpt = m.ckpt_elems(8, SEQ_LEN);
        t.row(&[
            m.name.into(),
            m.n_layers.to_string(),
            m.n_heads.to_string(),
            m.hidden.to_string(),
            format!("{:.1}B", m.params_total(SEQ_LEN) as f64 / 1e9),
            format!("{:.2e}", m.params_per_layer() as f64),
            fmt_bytes((m.n_layers * m.layer_opt_state_bytes()) as f64),
            format!("{:.2e}", ckpt as f64),
            format!("{:.1}x", m.params_per_layer() as f64 / ckpt as f64),
        ]);
    }
    t.emit(Some("bench_out/tab02_models.tsv"));
}
