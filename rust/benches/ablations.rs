//! Ablations on the REAL stack (tiny preset): which GreedySnake design
//! choices matter. Each row trains the same model/data and reports
//! wall-clock per step + final loss — optimizer overlap on/off, delay ratio
//! α, SSD-offloaded vs CPU-resident optimizer state, and the Rust fused
//! Adam vs the AOT Pallas kernel.

use greedysnake::coordinator::TrainerConfig;
use greedysnake::runtime::Manifest;
use greedysnake::trainer::{train, ScheduleKind};
use greedysnake::util::table::Table;

fn base(tag: &str) -> TrainerConfig {
    TrainerConfig {
        alpha: 0.25,
        opt_on_ssd: true,
        ssd_read_bps: 1.5e8, // deliberately tight so the optimizer I/O matters
        ssd_write_bps: 1.5e8,
        ssd_path: std::env::temp_dir().join(format!("gs_abl_{tag}_{}", std::process::id())),
        ..Default::default()
    }
}

fn main() -> anyhow::Result<()> {
    let steps = 10u64;
    let m = 4usize;
    let variants: Vec<(&str, TrainerConfig)> = vec![
        ("full (overlap, α=0.25, SSD opt)", base("full")),
        ("no overlap", TrainerConfig { overlap: false, ..base("noov") }),
        ("α = 0 (no delayed step)", TrainerConfig { alpha: 0.0, ..base("a0") }),
        ("α = 0.5", TrainerConfig { alpha: 0.5, ..base("a5") }),
        ("opt states CPU-resident", TrainerConfig { opt_on_ssd: false, ..base("cpu") }),
        (
            "AOT Pallas Adam (inline)",
            TrainerConfig { use_hlo_adam: true, ..base("hlo") },
        ),
    ];

    let mut t = Table::new(
        "Ablations — tiny GPT, 10 steps × 4 micro-batches, throttled SSD",
        &["variant", "s/step", "final loss", "ssd read/step"],
    );
    for (name, cfg) in variants {
        let log = train(
            Manifest::load("artifacts/tiny")?,
            cfg,
            ScheduleKind::Vertical,
            steps,
            m,
            0,
        )?;
        let mean_s: f64 = log.step_seconds.iter().sum::<f64>() / steps as f64;
        t.row(&[
            name.into(),
            format!("{mean_s:.3}"),
            format!("{:.4}", log.final_loss()),
            greedysnake::util::stats::fmt_bytes(log.ssd_read as f64 / steps as f64),
        ]);
    }
    t.emit(Some("bench_out/ablations.tsv"));
    println!("(expected: overlap + α>0 cut s/step under the tight SSD throttle; all losses match)");
    Ok(())
}
