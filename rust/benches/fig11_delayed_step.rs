//! Figure 11 — training throughput with and without the delayed optimizer
//! step (GPT-65B, 1×A100), with the chosen α annotated per batch size.
//! Both series saturate at the same ceiling; the delayed series gets there
//! at a smaller batch.

use greedysnake::lp;
use greedysnake::machine::MACHINE2_A100;
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::{StorageRatios, SystemParams};
use greedysnake::sim::{simulate, Schedule};
use greedysnake::util::table::Table;

fn main() {
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    let mut t = Table::new(
        "Fig. 11 — GPT-65B 1×A100: delayed optimizer step on/off (tokens/s)",
        &["global batch", "α=0", "delayed (α*)", "α* chosen", "gain"],
    );
    let mut sat_m = (None, None); // first m within 98% of ceiling, per series
    let ms: Vec<u64> = vec![2, 4, 8, 12, 16, 24, 32, 48, 64, 96];
    // ceiling estimated at large m with α=0
    let x0 = lp::solve_config(&sp, 96, 0.01).map(|r| r.ratios).unwrap_or(StorageRatios::ALL_SSD);
    let ceiling = simulate(&sp, 96, Schedule::GreedySnake { alpha: 0.0, x: x0 }).tokens_per_s;

    for &m in &ms {
        let x = lp::solve_config(&sp, m, 0.01)
            .map(|r| r.ratios)
            .unwrap_or(StorageRatios::ALL_SSD);
        let off = simulate(&sp, m, Schedule::GreedySnake { alpha: 0.0, x });
        // argmax over the α grid (coarse, like Algorithm 1)
        let mut best = (0.0f64, off.tokens_per_s);
        for i in 1..=10 {
            let a = i as f64 * 0.05;
            let xa = lp::solve_config(&sp, m, a).map(|r| r.ratios).unwrap_or(x);
            let r = simulate(&sp, m, Schedule::GreedySnake { alpha: a, x: xa });
            if r.tokens_per_s > best.1 {
                best = (a, r.tokens_per_s);
            }
        }
        if sat_m.0.is_none() && off.tokens_per_s > 0.98 * ceiling {
            sat_m.0 = Some(m);
        }
        if sat_m.1.is_none() && best.1 > 0.98 * ceiling {
            sat_m.1 = Some(m);
        }
        t.row(&[
            (m * 2).to_string(),
            format!("{:.0}", off.tokens_per_s),
            format!("{:.0}", best.1),
            format!("{:.0}%", best.0 * 100.0),
            format!("{:+.1}%", 100.0 * (best.1 / off.tokens_per_s - 1.0)),
        ]);
    }
    t.emit(Some("bench_out/fig11_delayed_step.tsv"));
    println!(
        "saturation batch: α=0 at {:?}, delayed at {:?} (paper: delay reaches saturation at smaller batch)",
        sat_m.0.map(|m| m * 2),
        sat_m.1.map(|m| m * 2),
    );
}
