//! Fig. 16 (multi-path planner panel) — the MLP-Offload-style multi-path
//! `PlannedStore` against its single-path ancestors: single NVMe vs
//! striped-2 vs planned DRAM + 2×NVMe + remote.
//!
//! * **simulated** (GPT-65B on the A100 node): an SSD-bound placement with
//!   the SSD tier at (a) one device, (b) 2 striped devices, (c) the planned
//!   multi-path aggregate (`sim::planned_bandwidth` — Σ path rates until a
//!   path saturates, fed into `sim::simulate_planned`);
//! * **closed forms** (`traffic::Workload::planned_read_bytes`): per-path
//!   byte counts that conserve the aggregate store traffic exactly;
//! * **direct store** (always runs): a throttled `PlannedStore`
//!   (DRAM 30 MB/s + 2×NVMe 10 MB/s + remote 10 MB/s) must read at ≥ 1.5×
//!   the measured bandwidth of its best single path, and its per-path
//!   `path_stats` counters must equal the `plan_shares` closed forms
//!   byte-for-byte;
//! * **real runtime** (when the AOT artifacts are built): a planned
//!   throttled run must be bit-identical to the single-SSD baseline with
//!   equal whole-object counters, and strictly faster.
//!
//! Emits `bench_out/fig16_mlp.json` (uploaded as a CI artifact) plus a
//! human-readable table.

use std::collections::BTreeMap;
use std::time::Instant;

use greedysnake::coordinator::TrainerConfig;
use greedysnake::machine::MACHINE2_A100;
use greedysnake::memory::{
    path_weight, plan_shares, PlannedConfig, PlannedStore, SsdStorage, TensorStore,
};
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::{StorageRatios, SystemParams};
use greedysnake::sim::{planned_bandwidth, simulate_planned, simulate_store, Schedule};
use greedysnake::traffic::Workload;
use greedysnake::trainer::{train, RunLog, ScheduleKind};
use greedysnake::util::json::Json;
use greedysnake::util::table::Table;

fn main() {
    let m = 16u64;
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    let x = StorageRatios::ALL_SSD; // the storage tier IS the bottleneck
    let sched = Schedule::GreedySnake { alpha: 0.0, x };
    let wl = Workload { model: GPT_65B, micro_batch: 2, seq_len: SEQ_LEN, m, shards: 1 };

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("model".to_string(), Json::Str("gpt-65b".to_string()));
    report.insert("machine".to_string(), Json::Str("a100".to_string()));
    report.insert("schedule".to_string(), Json::Str(sched.kind_name()));
    report.insert("m".to_string(), Json::Num(m as f64));

    // ---- sim sweep --------------------------------------------------------
    // Planned path set: DRAM (8 GB/s) + the machine's two NVMe devices +
    // a 200 MB/s remote tier; shares proportional to the plan weights, so
    // the aggregate law lands exactly on Σ path rates.
    let (r_bw, w_bw) = (sp.node.machine.ssd_read_bw, sp.node.machine.ssd_write_bw);
    let read_rates = [PlannedStore::DRAM_BPS, r_bw, r_bw, 200e6];
    let write_rates = [PlannedStore::DRAM_BPS, w_bw, w_bw, 200e6];
    let weights: Vec<u64> = read_rates.iter().map(|&b| path_weight(b)).collect();
    let shares = plan_shares(1 << 20, &weights);
    let agg_r = planned_bandwidth(&shares, &read_rates);
    let agg_w = planned_bandwidth(&shares, &write_rates);
    let single = simulate_store(&sp, m, sched, usize::MAX, 1, 0);
    let striped = simulate_store(&sp, m, sched, usize::MAX, 2, 0);
    let planned = simulate_planned(&sp, m, sched, usize::MAX, agg_r, agg_w, 0);
    assert!(
        striped.t_iter < single.t_iter,
        "striped-2 sim {} must beat single {}",
        striped.t_iter,
        single.t_iter
    );
    // <= not <: past the point where the aggregate outruns compute, extra
    // path bandwidth cannot shrink t_iter further (the sim's compute floor)
    assert!(
        planned.t_iter <= striped.t_iter,
        "planned multi-path sim {} must not trail striped-2 {}",
        planned.t_iter,
        striped.t_iter
    );
    assert!(
        planned.t_iter < single.t_iter,
        "planned multi-path sim {} must beat single {}",
        planned.t_iter,
        single.t_iter
    );
    let mut t = Table::new(
        "Fig. 16 (multi-path planner) — GPT-65B A100, all-SSD placement",
        &["backend", "t_iter (s)", "tokens/s", "speedup vs single"],
    );
    let mut sim_obj: BTreeMap<String, Json> = BTreeMap::new();
    for (name, r) in [
        ("single-nvme", single),
        ("striped-2", striped),
        ("planned-dram+2nvme+remote", planned),
    ] {
        t.row(&[
            name.to_string(),
            format!("{:.2}", r.t_iter),
            format!("{:.0}", r.tokens_per_s),
            format!("{:.2}x", single.t_iter / r.t_iter),
        ]);
        let mut o = BTreeMap::new();
        o.insert("t_iter_s".to_string(), Json::Num(r.t_iter));
        o.insert("tokens_per_s".to_string(), Json::Num(r.tokens_per_s));
        o.insert(
            "speedup_vs_single".to_string(),
            Json::Num(single.t_iter / r.t_iter),
        );
        sim_obj.insert(name.to_string(), Json::Obj(o));
    }
    t.emit(Some("bench_out/fig16_mlp.tsv"));
    report.insert("sim".to_string(), Json::Obj(sim_obj));

    // ---- closed forms -----------------------------------------------------
    let per_path = wl.planned_read_bytes(true, true, &weights);
    assert_eq!(
        per_path.iter().sum::<u64>(),
        wl.store_read_bytes(true, true),
        "planned per-path bytes must conserve the aggregate store traffic"
    );
    let mut forms: BTreeMap<String, Json> = BTreeMap::new();
    forms.insert(
        "store_read_bytes_per_iter".to_string(),
        Json::Num(wl.store_read_bytes(true, true) as f64),
    );
    forms.insert(
        "planned_read_bytes_per_path".to_string(),
        Json::Arr(per_path.iter().map(|&b| Json::Num(b as f64)).collect()),
    );
    forms.insert(
        "path_weights".to_string(),
        Json::Arr(weights.iter().map(|&w| Json::Num(w as f64)).collect()),
    );
    forms.insert("aggregate_read_bps".to_string(), Json::Num(agg_r));
    report.insert("closed_forms".to_string(), Json::Obj(forms));
    println!(
        "closed forms: per-iter store reads {} over {} paths (aggregate {:.1} GB/s)",
        greedysnake::util::stats::fmt_bytes(wl.store_read_bytes(true, true) as f64),
        per_path.len(),
        agg_r / 1e9,
    );

    // ---- direct-store leg (always runs): throttled multi-path reads -------
    // DRAM 30 MB/s + 2×NVMe 10 MB/s + remote 10 MB/s → weights [30,10,10,10]
    // and a 60 MB/s aggregate; the best single path moves 30 MB/s. The
    // measured planned read bandwidth must clear 1.5× the measured best
    // single path (theory: 2×).
    let tmp = |tag: &str| {
        std::env::temp_dir().join(format!("gs_f16_{tag}_{}", std::process::id()))
    };
    let pc = PlannedConfig {
        nvme: vec![(10e6, f64::INFINITY); 2],
        dram_capacity: 64 << 20,
        dram_bps: 30e6,
        remote_bps: 10e6,
    };
    let store = PlannedStore::create(tmp("planned"), &pc).expect("planned store");
    let obj_len: u64 = 8 << 20;
    let data: Vec<u8> = (0..obj_len).map(|i| (i % 251) as u8).collect();
    store.put("opt_obj", &data).expect("planned put");
    // per-path exactness: the runtime counters ARE the plan_shares closed
    // form (same weights, no DRAM spill at this capacity)
    let expect = plan_shares(obj_len, store.weights());
    let ps = store.path_stats();
    assert_eq!(ps.dram_written, expect[0], "dram write attribution");
    assert_eq!(ps.nvme_written, vec![expect[1], expect[2]], "nvme write attribution");
    assert_eq!(ps.remote_written, expect[3], "remote write attribution");
    let reads = 4u64;
    let mut out = Vec::new();
    let t0 = Instant::now();
    for _ in 0..reads {
        store.get("opt_obj", &mut out).expect("planned get");
    }
    let planned_el = t0.elapsed().as_secs_f64();
    assert_eq!(out, data, "planned read must reassemble the object");
    let ps = store.path_stats();
    assert_eq!(ps.dram_read, reads * expect[0], "dram read attribution");
    assert_eq!(
        ps.nvme_read,
        vec![reads * expect[1], reads * expect[2]],
        "nvme read attribution"
    );
    assert_eq!(ps.remote_read, reads * expect[3], "remote read attribution");
    assert_eq!(ps.total_read(), store.bytes_read(), "path bytes conserve the counter");
    // best single path: one device at the DRAM path's 30 MB/s
    let flat = SsdStorage::create(tmp("flat"), 30e6, f64::INFINITY).expect("flat store");
    flat.put("opt_obj", &data).expect("flat put");
    let t0 = Instant::now();
    for _ in 0..reads {
        flat.get("opt_obj", &mut out).expect("flat get");
    }
    let single_el = t0.elapsed().as_secs_f64();
    let planned_bw = (reads * obj_len) as f64 / planned_el;
    let single_bw = (reads * obj_len) as f64 / single_el;
    println!(
        "direct store: planned {:.1} MB/s vs best single path {:.1} MB/s ({:.2}x)",
        planned_bw / 1e6,
        single_bw / 1e6,
        planned_bw / single_bw,
    );
    assert!(
        planned_bw >= 1.5 * single_bw,
        "planned aggregate read bandwidth {:.1} MB/s must clear 1.5x the best \
         single path {:.1} MB/s",
        planned_bw / 1e6,
        single_bw / 1e6,
    );
    let mut o = BTreeMap::new();
    o.insert("planned_read_mbps".to_string(), Json::Num(planned_bw / 1e6));
    o.insert("single_path_read_mbps".to_string(), Json::Num(single_bw / 1e6));
    o.insert("speedup".to_string(), Json::Num(planned_bw / single_bw));
    report.insert("direct_store".to_string(), Json::Obj(o));

    // ---- real-runtime leg (skips without AOT artifacts) -------------------
    let runtime_status = match greedysnake::runtime::test_artifacts("artifacts/tiny") {
        None => {
            println!("runtime planned leg: skipped (artifacts/tiny not built)");
            "skipped".to_string()
        }
        Some(_) => {
            let mk = |tag: &str, planned: bool| TrainerConfig {
                alpha: 0.0,
                opt_on_ssd: true,
                ckpt_on_ssd: true,
                overlap: false,
                io_depth: 0,
                ssd_read_bps: 4e6,
                ssd_write_bps: 4e6,
                ssds: if planned { 2 } else { 1 },
                cpu_cache_mb: if planned { 16 } else { 0 },
                planned,
                remote_mbps: if planned { 200.0 } else { 0.0 },
                ssd_path: tmp(tag),
                ..Default::default()
            };
            let manifest = || greedysnake::runtime::Manifest::load("artifacts/tiny").unwrap();
            let go = |tag: &str, planned: bool| -> RunLog {
                train(manifest(), mk(tag, planned), ScheduleKind::Vertical, 3, 3, 0).unwrap()
            };
            let single = go("rt_s", false);
            let planned = go("rt_p", true);
            assert_eq!(single.losses, planned.losses, "planned: losses diverged");
            assert_eq!(
                single.param_sq_norm.to_bits(),
                planned.param_sq_norm.to_bits(),
                "planned: parameters diverged"
            );
            assert_eq!(
                single.moment_sq_norm.to_bits(),
                planned.moment_sq_norm.to_bits(),
                "planned: moments diverged"
            );
            // whole-object counter equality: the plan never changes bytes
            assert_eq!(single.ssd_read, planned.ssd_read, "planned counters diverged");
            assert_eq!(single.ssd_written, planned.ssd_written);
            let t1: f64 = single.step_seconds.iter().sum();
            let t2: f64 = planned.step_seconds.iter().sum();
            assert!(
                t2 < t1,
                "planned runtime {t2:.3}s must strictly undercut single {t1:.3}s"
            );
            let mut o = BTreeMap::new();
            o.insert("single_wall_s".to_string(), Json::Num(t1));
            o.insert("planned_wall_s".to_string(), Json::Num(t2));
            o.insert("ssd_read_bytes".to_string(), Json::Num(planned.ssd_read as f64));
            report.insert("runtime".to_string(), Json::Obj(o));
            println!("runtime planned leg: single {t1:.2}s vs planned {t2:.2}s");
            "ok".to_string()
        }
    };
    report.insert("runtime_status".to_string(), Json::Str(runtime_status));

    std::fs::create_dir_all("bench_out").expect("create bench_out");
    let path = "bench_out/fig16_mlp.json";
    std::fs::write(path, Json::Obj(report).to_string_compact()).expect("write planner report");
    println!("planner report -> {path}");
}
