//! Figure 5 — GPU load/offload traffic: horizontal vs vertical scheduling
//! for GPT-65B (micro-batch 8, like the paper's §3.4 example), swept over
//! the micro-batch count M.

use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::traffic::Workload;
use greedysnake::util::stats::fmt_bytes;
use greedysnake::util::table::Table;

fn main() {
    let mut t = Table::new(
        "Fig. 5 — per-iteration GPU traffic, GPT-65B mb=8 (load | offload)",
        &["M", "horiz load", "horiz offload", "vert load", "vert offload", "reduction"],
    );
    for m in [2u64, 4, 8, 16, 32] {
        let wl = Workload { model: GPT_65B, micro_batch: 8, seq_len: SEQ_LEN, m, shards: 1 };
        let h = wl.horizontal();
        let v = wl.vertical();
        t.row(&[
            m.to_string(),
            fmt_bytes(h.total_load() as f64),
            fmt_bytes(h.total_store() as f64),
            fmt_bytes(v.total_load() as f64),
            fmt_bytes(v.total_store() as f64),
            format!("{:.2}x", h.total() as f64 / v.total() as f64),
        ]);
    }
    t.emit(Some("bench_out/fig05_traffic.tsv"));

    // the §3.4 element-count claim: layer ≈ 6× a micro-batch-8 checkpoint
    let per_layer = GPT_65B.params_per_layer() as f64;
    let ckpt = GPT_65B.ckpt_elems(8, SEQ_LEN) as f64;
    println!(
        "per-layer params {per_layer:.3e} vs mb-8 checkpoint {ckpt:.3e} elements = {:.1}x (paper: 6x)",
        per_layer / ckpt
    );
}
