//! Figure 12 (scaling panel) — multi-worker throughput under ONE shared
//! SSD: W ∈ {1, 2, 4} data-parallel workers training GPT-65B on the A100
//! node, simulated with per-worker compute resources, the ring all-reduce,
//! and the rank-0 optimizer (`sim::simulate_dist`). Every worker re-reads
//! the full SSD-resident parameter share each pass, so the shared tier's
//! pressure grows with W and the speedup curve is sub-linear — the
//! contention effect behind the paper's 1.93× (not 4×) 4-GPU result.
//!
//! Emits a machine-readable report to `bench_out/fig12_scaling.json`
//! (uploaded as a CI artifact) plus a human-readable table comparing one
//! shared SSD against two.

use std::collections::BTreeMap;

use greedysnake::lp;
use greedysnake::machine::MACHINE2_A100;
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::{StorageRatios, SystemParams};
use greedysnake::sim::{simulate_dist, DistConfig, Schedule, SimResult};
use greedysnake::traffic::Workload;
use greedysnake::util::json::Json;
use greedysnake::util::table::Table;

fn result_json(r: &SimResult, speedup: f64) -> Json {
    let mut o = BTreeMap::new();
    o.insert("t_iter_s".to_string(), Json::Num(r.t_iter));
    o.insert("tokens_per_s".to_string(), Json::Num(r.tokens_per_s));
    o.insert("tflops_per_gpu".to_string(), Json::Num(r.tflops_per_gpu));
    o.insert("gpu_util".to_string(), Json::Num(r.gpu_util));
    o.insert("speedup_vs_w1".to_string(), Json::Num(speedup));
    Json::Obj(o)
}

fn main() {
    let m = 32u64;
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    // the LP's preferred placement at this batch (α pinned low: the dist
    // sim models the α = 0 configuration)
    let x = lp::solve_config(&sp, m, 0.01)
        .map(|r| r.ratios)
        .unwrap_or(StorageRatios::ALL_SSD);
    let sched = Schedule::GreedySnake { alpha: 0.0, x };
    let wl = Workload { model: GPT_65B, micro_batch: 2, seq_len: SEQ_LEN, m, shards: 1 };

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("model".to_string(), Json::Str("gpt-65b".to_string()));
    report.insert("machine".to_string(), Json::Str("a100".to_string()));
    report.insert("schedule".to_string(), Json::Str(sched.kind_name()));
    report.insert("m_global".to_string(), Json::Num(m as f64));

    let mut t = Table::new(
        "Fig. 12 (scaling) — GPT-65B A100, W workers over shared SSDs (tokens/s)",
        &["W", "1 SSD", "speedup", "2 SSDs", "speedup", "all-reduce/worker"],
    );
    let dist = |w: usize, ssds: usize| DistConfig { workers: w, ssds, ..DistConfig::default() };
    let base1 = simulate_dist(&sp, m, sched, dist(1, 1));
    let base2 = simulate_dist(&sp, m, sched, dist(1, 2));
    let mut shared: BTreeMap<String, Json> = BTreeMap::new();
    let mut dual: BTreeMap<String, Json> = BTreeMap::new();
    let mut last_speedup = 1.0;
    for w in [1usize, 2, 4] {
        let one = simulate_dist(&sp, m, sched, dist(w, 1));
        let two = simulate_dist(&sp, m, sched, dist(w, 2));
        let s1 = base1.t_iter / one.t_iter;
        let s2 = base2.t_iter / two.t_iter;
        t.row(&[
            w.to_string(),
            format!("{:.0}", one.tokens_per_s),
            format!("{s1:.2}x"),
            format!("{:.0}", two.tokens_per_s),
            format!("{s2:.2}x"),
            greedysnake::util::stats::fmt_bytes(wl.allreduce_bytes_per_worker(w as u64) as f64),
        ]);
        shared.insert(w.to_string(), result_json(&one, s1));
        dual.insert(w.to_string(), result_json(&two, s2));
        last_speedup = s1;
    }
    t.emit(Some("bench_out/fig12_scaling.tsv"));
    report.insert("workers_1ssd".to_string(), Json::Obj(shared));
    report.insert("workers_2ssd".to_string(), Json::Obj(dual));

    println!(
        "W=4 speedup over one shared SSD: {last_speedup:.2}x (sub-linear; paper: 1.93x over \
         ZeRO-Infinity at 4 GPUs with the SSD shared)"
    );

    std::fs::create_dir_all("bench_out").expect("create bench_out");
    let path = "bench_out/fig12_scaling.json";
    std::fs::write(path, Json::Obj(report).to_string_compact()).expect("write scaling report");
    println!("scaling report -> {path}");
}
